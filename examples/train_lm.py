"""End-to-end LM training driver: train a model a few hundred steps on the
synthetic-motif dataset and watch the loss drop, with checkpoint/restart
exercised mid-run.

Default is an ~8M-param model sized for this 1-core CPU container
(~1 s/step); pass --hundred-m for the ~100M configuration on real hardware
(the deliverable-scale run: identical code path, bigger dims).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLMData
from repro.models import model as M
from repro.models.transformer import ArchConfig, LayerSpec
from repro.optim.adamw import adamw_init
from repro.runtime import FaultTolerantRunner, RunnerConfig


def hundred_m_config() -> ArchConfig:
    """~100M params: a scaled qwen2-style decoder (real-hardware scale)."""
    return ArchConfig(
        name="demo_100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_head=64, d_ff=3072, vocab=16384,
        period=(LayerSpec(kind="attn"),), qkv_bias=True,
        tie_embeddings=True, norm="rmsnorm", act="swiglu", remat=False)


def eight_m_config() -> ArchConfig:
    """~8M params: the same family sized for a 1-core CPU demo."""
    return ArchConfig(
        name="demo_8m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_head=64, d_ff=1024, vocab=4096,
        period=(LayerSpec(kind="attn"),), qkv_bias=True,
        tie_embeddings=True, norm="rmsnorm", act="swiglu", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param config (real-hardware scale)")
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    cfg = hundred_m_config() if args.hundred_m else eight_m_config()
    print(f"model: {cfg.name}, {M.n_params(cfg):,} params")
    data = SyntheticLMData(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch, seed=3))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    train_step = jax.jit(M.make_train_step(cfg, lr_peak=6e-4,
                                           total_steps=args.steps))

    def stepper(p, o, batch):
        return train_step(p, o, {k: jnp.asarray(v) for k, v in batch.items()})

    boom = {"armed": args.inject_failure}

    def failure_hook(step):
        if boom["armed"] and step == args.steps // 2:
            boom["armed"] = False
            raise RuntimeError("injected mid-run preemption")

    with tempfile.TemporaryDirectory() as d:
        runner = FaultTolerantRunner(
            RunnerConfig(total_steps=args.steps, checkpoint_every=50),
            train_step=stepper, data=data, ckpt=CheckpointManager(d),
            failure_hook=failure_hook)
        t0 = time.time()
        params, opt = runner.run(params, opt)
        dt = time.time() - t0

    hist = runner.metrics_history
    w = 20
    first = sum(h["loss"] for h in hist[:w]) / w
    last = sum(h["loss"] for h in hist[-w:]) / w
    print(f"{len(hist)} recorded steps in {dt:.0f}s "
          f"(restarts survived: {runner.restarts})")
    print(f"loss: first-{w}-avg {first:.3f} -> last-{w}-avg {last:.3f}")
    assert last < first - 0.5, "model failed to learn the motif structure"
    print("OK: loss dropped; checkpoint/restart exercised" if runner.restarts
          else "OK: loss dropped")


if __name__ == "__main__":
    main()
