"""End-to-end CFD driver (paper §VI / Alg. 2): SIMPLE lid-driven cavity.

Every outer iteration forms the u/v momentum and pressure-correction systems
and solves them through the repo's operator/solver/preconditioner registries
— the exact structure the paper proposes for MFIX on the CS-1 (5 solver
iterations for momentum, 20 for continuity).  Prints the residual history
and an ASCII streamfunction.

    PYTHONPATH=src python examples/cfd_cavity.py --n 32 --re 100
    PYTHONPATH=src python examples/cfd_cavity.py --backend spmd --precond jacobi
"""

import argparse

import numpy as np

from repro.apps.cfd import CavityConfig, SolverOptions, centerline_u, solve_cavity
from repro.launch.mesh import make_mesh_for_devices


def ascii_stream(u, v, n=16):
    """Coarse ASCII rendering of the flow (speed magnitude)."""
    uc = 0.5 * (np.asarray(u)[1:, :] + np.asarray(u)[:-1, :])
    vc = 0.5 * (np.asarray(v)[:, 1:] + np.asarray(v)[:, :-1])
    speed = np.sqrt(uc ** 2 + vc ** 2)
    sx = max(1, speed.shape[0] // n)
    sy = max(1, speed.shape[1] // n)
    s = speed[::sx, ::sy]
    chars = " .:-=+*#%@"
    q = (s / (s.max() + 1e-9) * (len(chars) - 1)).astype(int)
    rows = ["".join(chars[c] for c in q[:, j]) for j in range(s.shape[1] - 1, -1, -1)]
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--re", type=float, default=100.0)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--backend", default="reference", choices=["reference", "spmd"])
    ap.add_argument("--precond", default="none")
    args = ap.parse_args()

    cfg = CavityConfig(n=args.n, reynolds=args.re, outer_iters=args.iters,
                       tol=5e-6)
    opts = SolverOptions(backend=args.backend, precond=args.precond)
    mesh = make_mesh_for_devices() if args.backend != "reference" else None
    u, v, p, hist = solve_cavity(cfg, opts, mesh)
    print(f"SIMPLE outer iterations: {len(hist)} "
          f"(continuity residual {hist[0]:.2e} -> {hist[-1]:.2e})")
    cl = np.asarray(centerline_u(u))
    print(f"centerline u: min={cl.min():.3f} (Ghia Re=100 reference ~ -0.21 "
          f"on a fine grid; first-order upwind on {args.n}^2 is diffusive)")
    print("\nflow speed (lid at top):")
    print(ascii_stream(u, v))


if __name__ == "__main__":
    main()
