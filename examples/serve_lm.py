"""Batched serving example: prefill a batch of prompts, then decode tokens
autoregressively with the KV-cache serve path (the decode_32k/long_500k cell
machinery at CPU scale), reporting per-phase token throughput.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2_1_5b --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    B = args.batch
    max_len = args.prompt_len + args.tokens + (
        cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    caches = M.init_caches(cfg, B, max_len)

    key = jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)}
    if cfg.frontend == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(
            key, (B, args.prompt_len, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(M.make_prefill_step(cfg, M.SHAPES["smoke_prefill"]))
    serve = jax.jit(M.make_serve_step(cfg))

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)[:, 0]]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, caches = serve(params, {"token": tok}, caches)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"generated={gen.shape[1]} tokens/request")
    print(f"prefill: {t_prefill*1e3:.0f} ms "
          f"({B*args.prompt_len/t_prefill:.0f} tok/s, incl. compile)")
    print(f"decode:  {t_decode*1e3:.0f} ms "
          f"({B*(args.tokens-1)/t_decode:.0f} tok/s)")
    print("sample token ids (request 0):", gen[0, :16].tolist())
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
