"""Quickstart: solve a 7-point stencil system with distributed mixed-precision
BiCGStab — the paper's experiment in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py   # multi-device fabric
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bicgstab, precision, stencil
from repro.launch.mesh import make_mesh_for_devices


def main():
    # A convection-diffusion system (nonsymmetric, diagonally dominant) on a
    # 48 x 48 x 32 mesh, diagonally preconditioned to unit diagonal (paper §IV).
    shape = (48, 48, 32)
    coeffs = stencil.convection_diffusion(shape, peclet=5.0)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(coeffs, x_true)

    # Map the mesh onto the available chip fabric (Fig. 3) and solve in the
    # paper's mixed precision: bf16 storage/arithmetic, f32 reductions.
    mesh = make_mesh_for_devices()
    print(f"fabric: {dict(mesh.shape)}")
    result = bicgstab.solve_distributed(
        mesh, coeffs, b.astype(jnp.bfloat16),
        tol=1e-7, maxiter=200, policy=precision.MIXED,
    )
    print(f"converged={bool(result.converged)} in {int(result.iterations)} iters")

    err = np.abs(np.asarray(result.x, np.float32) - np.asarray(x_true)).max()
    print(f"max error vs manufactured solution (bf16 plateau): {err:.2e}")

    # Beyond the paper: iterative refinement recovers f32 accuracy while the
    # inner solver stays 16-bit (§VI-B made concrete).
    x, rels = bicgstab.solve_refined(coeffs, b, mesh=mesh,
                                     inner_policy=precision.MIXED)
    err = np.abs(np.asarray(x) - np.asarray(x_true)).max()
    print(f"after refinement: true-residual {float(rels[-1]):.2e}, "
          f"max error {err:.2e}")


if __name__ == "__main__":
    main()
