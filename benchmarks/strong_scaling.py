"""Paper Figs. 7-8: strong scaling of solve time vs core count.

The paper shows Joule (Xeon cluster) scaling from 75 ms/iter (1024 cores) to
~6 ms/iter (16k cores) on a 600^3 mesh, vs 28.1 us on the CS-1, and a smaller
370^3 mesh that stops scaling beyond 8k cores.

Here: (a) measured CPU strong scaling over fake-device fabrics (1->8
devices, fixed problem) exercising the real halo/AllReduce code path;
(b) the TPU roofline model's scaling curve for the paper meshes at
{64, 128, 256, 512} chips (memory term scales with per-chip volume; the
AllReduce latency floor does not).
"""

import os
import subprocess
import sys


def _measure(n_devices: int, shape=(32, 32, 32), iters: int = 30) -> float:
    """Per-iteration seconds on an n-device CPU fabric (subprocess)."""
    code = f"""
import time, jax, jax.numpy as jnp
from repro.core import bicgstab, precision, stencil
from repro.launch.mesh import make_mesh_for_devices
shape = {shape!r}
cf = stencil.convection_diffusion(shape)
b = stencil.rhs_for_solution(cf, jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32))
mesh = make_mesh_for_devices({n_devices})
solve = jax.jit(lambda c, bb: bicgstab.solve_distributed(
    mesh, c, bb, tol=1e-30, maxiter={iters}, policy=precision.F32))
res = solve(cf, b); jax.block_until_ready(res.x)
t0 = time.time(); res = solve(cf, b); jax.block_until_ready(res.x)
print((time.time() - t0) / {iters})
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return float(out.stdout.strip().splitlines()[-1])


def run(fast: bool = False) -> list[str]:
    rows = []
    # (a) measured: fixed 32^3-ish problem across 1/2/4/8 CPU devices.
    for n in (1, 2, 4, 8):
        dt = _measure(n)
        rows.append(f"strong_scaling,cpu_{n}dev_us_per_iter,{dt * 1e6:.0f}")
    # (b) roofline model across chip counts for the paper meshes
    from repro.core.perfmodel import iteration_time_model
    for mesh_name, mshape in (("600cube", (608, 608, 608)),
                              ("370cube", (384, 384, 370)),
                              ("cs1_paper", (608, 608, 1536))):
        for chips in (64, 128, 256, 512):
            t = iteration_time_model(mshape, chips)
            rows.append(f"strong_scaling,tpu_model_{mesh_name}_{chips}chips_us,"
                        f"{t['t_iter_s'] * 1e6:.1f}")
    rows.append("strong_scaling,joule_600cube_16k_cores_us,6000")
    rows.append("strong_scaling,cs1_measured_us,28.1")
    return rows
