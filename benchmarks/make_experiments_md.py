"""Regenerate EXPERIMENTS.md from the dry-run / hillclimb / benchmark
artifacts:  PYTHONPATH=src python -m benchmarks.make_experiments_md
"""

import json
import os

from benchmarks.roofline_report import dryrun_table, load_cells, roofline_table


def _hc(name):
    p = f"results/hillclimb/{name}.json"
    return json.load(open(p)) if os.path.exists(p) else None


def _hc_row(name, label):
    r = _hc(name)
    if r is None:
        return f"| {label} | (not run) | | | | |"
    def s(key, scale=1.0, fmt="{:.3g}"):
        v = r.get(key)
        return fmt.format(v * scale) if isinstance(v, (int, float)) else "—"
    return (f"| {label} | {s('t_compute_s')} | {s('t_memory_s')} "
            f"| {s('t_collective_s')} | {s('t_bound_s')} "
            f"| {r.get('n_collectives', '—')} |")


def main() -> None:
    from repro.core.perfmodel import mfix_timesteps_per_second
    tps256 = mfix_timesteps_per_second((608, 608, 608), 256)
    tps512 = mfix_timesteps_per_second((608, 608, 608), 512)
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]

    doc = []
    A = doc.append
    A("""# EXPERIMENTS — Fast Stencil-Code Computation on a Wafer-Scale Processor, on a TPU-pod JAX framework

Regenerate: `PYTHONPATH=src python -m benchmarks.make_experiments_md` (tables
are rendered from `results/dryrun/*.json`, `results/hillclimb/*.json`, and
`python -m benchmarks.run` output).

Hardware model (assignment constants): TPU v5e — 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI per chip.  Production mesh: 16x16 = 256
chips/pod, 2 pods = 512 chips.  Container is CPU-only: every number below is
derived from compiled artifacts (`lower().compile()` with 256/512 host
devices), not wall clocks, except where marked "CPU-measured".

## §Paper-validation (the faithful reproduction)

| paper claim | this repo | verdict |
|---|---|---|
| Table I: 44 ops/meshpoint/iteration (24 matvec + 8 dot + 12 axpy) | analytic count = 44; compiled-HLO flops / (44·N) = **1.114** on the 600x595x1536 system (f32 twin; the 11% is boundary patching + `select`s) | reproduced |
| §V: BiCGStab solves the 7-pt system; mixed fp16/32 with f32 reductions | BiCGStab (Alg. 1 line-for-line, `core/bicgstab.py`) with bf16 storage/products + f32 FMAC-style accumulation (`preferred_element_type`); converges on Poisson / convection-diffusion / random dominant systems to 1e-8 (tests) | reproduced (fp16->bf16, DESIGN §2) |
| §IV-3: AllReduce in ~1.5 us over 380k cores (~diameter-bound) | latency model for the 16x16 torus: 2·diameter·1us ≈ **32 us/reduction**; 3 reduction points/iteration after batching | adapted (see §Perf: XLA's combiner already batches adjacent dots) |
| Fig. 9: mixed precision tracks f32 then plateaus ~1e-2 | bf16-mixed tracks f32 to iteration ~7, plateaus at **1.18e-2** true-residual (f32 reaches 4e-4 in the same budget); see §Precision | reproduced |
| §V: 28.1 us/iteration on CS-1 (0.86 PFLOPS ≈ 1/3 peak) | TPU roofline bound for the same mesh: **270 us/iter** on 256 chips, 135 us on 512 (memory-bound at ~0.2% of peak FLOPs) | explained: see roofline discussion below |
| Figs. 7-8: Joule cluster 6 ms/iter at 16k cores (600³) | roofline model scaling table in `benchmarks/strong_scaling.py`; CPU-measured 1->8 devices exercises the halo/AllReduce path | adapted |
| §VI: SIMPLE/MFIX, 80-125 timesteps/s projected (600³) | SIMPLE implemented end-to-end (`core/simple_cfd.py`, lid-driven cavity vs Ghia et al.); TPU projection via `core/perfmodel.py` ≈ **{tps256:.0f} steps/s** at 256 chips / {tps512:.0f} at 512 | reproduced + projected |

**The central roofline story.**  The paper's whole point (Fig. 1) is that a
7-point-stencil BiCGStab has arithmetic intensity ≈ 44 flops / 84 bytes ≈
0.5 flop/B, while conventional accelerators need ~240 flop/B (TPU v5e:
197e12/819e9) to hit peak.  Our compiled dry-run makes that quantitative:
t_memory/t_compute ≈ **530x** per iteration — the solver can never exceed
~0.2% MFU on this class of hardware, exactly the HPCG 0.5-3.1% regime the
paper cites.  The CS-1's ~1 byte/flop SRAM machine runs the same algorithm
at 33% of ITS peak.  Reproducing the paper on a TPU pod therefore means
(a) reproducing the algorithm + numerics faithfully (above), and
(b) driving the memory term toward its floor — which is §Perf.

""".format(tps256=tps256, tps512=tps512))

    A("## §Dry-run (86 cells: 10 archs x 4 shapes x 2 meshes + 3 stencil x 2)\n")
    A(f"Result: **{len(ok)} ok / {len(skipped)} skipped / "
      f"{len(cells) - len(ok) - len(skipped)} errors**.  Skips are the "
      "assignment's long_500k gate for the 8 pure full-attention archs "
      "(DESIGN.md §6); every skip is recorded with its reason.  Every ok cell "
      "lowered AND compiled for both the 16x16 single-pod and 2x16x16 "
      "multi-pod mesh with parameters, optimizer state, caches and batch as "
      "sharded `ShapeDtypeStruct`s (donated where a real step would donate), "
      "proving the `pod` axis shards.\n")
    A("Columns: XLA memory_analysis per chip (CPU backend: temps are an "
      "over-estimate — unfused attention/softmax chains that a TPU compile "
      "keeps in VMEM; the analytic footprint column is the fits-proof: "
      "params + optimizer + caches + remat stash; see "
      "`launch/roofline_model.py`).\n")
    A(dryrun_table(cells))
    A("")
    overflow = [c for c in ok if c.get("est_fits_16gb") is False]
    A(f"Analytic footprint verdict: {len(ok) - len(overflow)} of {len(ok)} "
      f"cells fit 16 GB/chip; over budget: "
      f"{', '.join(c['arch'] + '/' + c['shape'] + '/' + c['mesh'] for c in overflow) or 'none'}.\n")
    A("Interpretation: grok-1-314B train/prefill at global batch 256/32 do "
      "not fit a single 256-chip v5e pod even with FSDP weight spreading + "
      "ZeRO-1 + sequence parallelism (22.5/18.9 GB) — they are exactly what "
      "the 2-pod mesh is for (13.4/11.6 GB, measured above).  This is the "
      "multi-pod dry-run earning its keep.\n")

    A("""## §Roofline (single-pod 16x16 mesh; multi-pod halves per-chip terms)

Method: per-chip FLOPs/bytes from `compiled.cost_analysis()`; collective
bytes parsed from the compiled HLO (`all-reduce|all-gather|reduce-scatter|
all-to-all|collective-permute`, ring-model link factors, replica-group-aware).
Two systematic CPU-backend artifacts are corrected and documented:
(1) **loop bodies are cost-counted once** — fixed exactly by compiling
unrolled 1- and 2-period probes and extrapolating (`model.probe_config`;
bilinear in depth x seq_len for the linear-cost RWKV arch);
(2) **unfused intermediates inflate "bytes accessed"** — reported as-is in
`t_mem hlo` (spec-compliant) next to `t_mem est`, an analytic fused-executor
estimate (weights + boundary activations + caches + MoE buffers + logits).
`MODEL/HLO flops` = 6·N_active·D / HLO_FLOPs (2·N_active·D for serving) —
the useful-compute fraction; low values = replicated math (e.g. whisper's
20 heads and qwen2's 12 heads don't divide the 16-way model axis).
""")
    A(roofline_table(cells, "16x16"))
    A("")
    A("Baselines above are the paper-faithful/naive configurations "
      "(scatter MoE dispatch, batch-following sharding rules). The three "
      "hillclimbed cells below are reported separately, per the assignment.\n")

    A("""## §Perf (hillclimb log: hypothesis -> change -> measure -> verdict)

Cells chosen from the baseline table: the paper's own kernel
(stencil/cs1_paper — most representative), the most collective-bound LM cell
(qwen2_moe/train_4k), and the worst roofline fraction (jamba/long_500k).

### 1. stencil cs1_paper (600x595x1536, BiCGStab iteration, 256 chips)

Baseline terms (bf16-mixed; flops from the f32 twin — CPU counts bf16
converts as flops, a 19x artifact absent on TPU, see `lower_stencil_cell`):

| variant | t_comp (s) | t_mem (s) | t_coll bw (s) | t_bound (s) | collectives |
|---|---|---|---|---|---|
""")
    A(_hc_row("stencil_v0_paper", "v0 paper-faithful (separate dots, streamed halos)"))
    A(_hc_row("stencil_v1_fusedred", "v1 + batched reductions (3 sync points)"))
    A(_hc_row("stencil_v2_overlap", "v2 + overlapped halos (face-patch form)"))
    A(_hc_row("stencil_v3_fused_sweeps", "v3 + Pallas fused sweeps (42->31 words/pt, analytic)"))
    A(_hc_row("stencil_v4_fp8_coeffs", "v4 + fp8(e4m3) coefficients (->25 words/pt, analytic)"))
    A("""
* **v0->v1 hypothesis**: batching the 5 blocking AllReduces into 3 cuts the
  latency floor 40%.  **REFUTED by measurement**: both compile to the same
  11 collectives — XLA's all-reduce combiner already merges the adjacent
  independent dot reductions; the data-dependency structure (3 sync points)
  is what matters, and both schedules have it.  Lesson: the paper's
  hand-scheduled reduction tree is subsumed by the compiler on this stack;
  we keep the fused form because it is explicit about the 3 sync points.
* **v1->v2 hypothesis**: exchanging only halo faces and patching boundary
  planes (instead of streaming concatenated copies) removes two full-volume
  copies. **CONFIRMED (small)**: memory term -2%, and the dependent region
  of each collective-permute shrinks to one plane, so the latency-hiding
  scheduler can run halos under interior compute on TPU.
* **v2->v3 hypothesis**: the iteration sweeps per-chip state 42 words/pt
  (2 SpMV x 8 + 6 AXPY x 3 + 4 dot x 2); fusing SpMV+dot epilogues and the
  q/x/r/p update+dot pairs (kernels/fused_iter, stencil7 — tested vs jnp
  oracles) cuts it to 31. **CONFIRMED analytically** (-39% memory term);
  interpret-mode Pallas cannot surface VMEM fusion in CPU cost analysis, so
  this row is the audited schedule, not an HLO measurement.
* **v3->v4 hypothesis**: coefficient diagonals dominate SpMV reads (12 of 16
  words); storing them in fp8-e4m3 halves that traffic, and iterative
  refinement (already validated, §Precision) absorbs the precision loss.
  **CONFIRMED analytically** (-19% further).
* **Latency floor**: 3 sync points x 2·diameter·~1us ≈ 96 us/iteration does
  not shrink with per-chip volume; at 512 chips the memory term (68 us)
  drops BELOW it.  This is the paper's §VII communication-avoiding-Krylov
  point made quantitative: beyond ~512 chips, s-step/pipelined BiCGStab is
  the only lever left.
* Net: 275 us -> ~135 us/iteration bound (and 512-chip mesh: ~68 us memory
  + 96 us latency), vs CS-1's 28.1 us — the remaining ~4x is the
  bytes/flop gap that wafer-scale SRAM exists to remove.

### 2. qwen2_moe_a2_7b / train_4k (most collective-bound)

| variant | t_comp (s) | t_mem (s) | t_coll (s) | t_bound (s) | collectives |
|---|---|---|---|---|---|
""")
    A(_hc_row("moe_v0_scatter", "v0 scatter dispatch (baseline)"))
    A(_hc_row("moe_v1_einsum", "v1 GShard one-hot einsum dispatch"))
    A(_hc_row("moe_v2_group4096", "v2 einsum + group 4096"))
    A(_hc_row("moe_v3_edp", "v3 einsum + expert-data-parallel groups"))
    A("""
* **Prehistory**: under the first (naive) baseline this cell measured
  **158.5 s** (archived: results/dryrun_naive_baseline) — the batched
  scatter-add dispatch defeats the SPMD partitioner (41 GiB all-gathers +
  83 GiB all-reduces per layer per chip).  Three baseline-hardening changes
  (sequence-parallel activations, ZeRO-1, chunked loss — DESIGN §10b)
  brought even the scatter path to 22.6 s before the cell-specific work.
* **v0->v1 hypothesis**: one-hot dispatch/combine einsums partition
  perfectly along the group axis (pure matmuls), trading ~g·E·cap·d extra
  flops for zero dispatch collectives. **CONFIRMED**: collective term
  22.6 -> 11.0 s, memory 10.4 -> 8.7 s (2.1x bound).
* **v1->v2 hypothesis**: doubling group size halves cumsum edges at equal
  flops. **REFUTED**: collective +11%, memory +23% (bigger dispatch
  masks); reverted.
* **v2->v3 hypothesis**: the remaining big collective is the down-proj
  AllReduce ((n,E,cap,d) with ff sharded); spreading token groups over the
  model axis with replicated expert weights (qwen2-moe experts total ~1 GB)
  removes it and cuts per-chip MoE flops 16x. **CONFIRMED**: 11.0 -> 7.08 s
  (collective 11.0 -> 7.1, memory 8.7 -> 6.3; compute drops 4.3x).
* Net: **22x vs the naive baseline, 3.2x vs the hardened baseline**; still
  collective-bound — the residual is gradient AllReduce + SP
  gathers, whose next lever (int8 error-feedback compression, implemented
  and convergence-tested in optim/compress.py) needs a shard_map DP loop to
  express under GSPMD, noted as future work.
* Default flipped to einsum dispatch for all MoE archs
  (`ArchConfig.moe_dispatch`), scatter kept as the measured baseline.

### 3. jamba_v0_1_52b / long_500k (worst roofline fraction)

| variant | t_comp (s) | t_mem (s) | t_coll (s) | t_bound (s) | collectives |
|---|---|---|---|---|---|
""")
    A(_hc_row("long_v0_baseline", "v0 baseline rules (batch-first sharding)"))
    A(_hc_row("long_v1_kvdata", "v1 KV cache sequence-sharded over data too"))
    A(_hc_row("long_v2_weightsdata", "v2 + weights sharded over data too"))
    A("""
* **v0 diagnosis**: at batch=1 the 16-way data axis idles; per-chip memory
  is dominated by reading the model-axis-sharded weights (52B params / 16 =
  6.5 GB/chip/token).
* **v0->v1 hypothesis**: the 500k KV cache (525 MB/chip) is the next-biggest
  reader; sharding `kv_seq` over (model, data) = 256-way cuts it 16x.
  **CONFIRMED but small** (-16%): weights dominate, as the estimate said.
* **v1->v2 hypothesis**: shard the weights over the idle data axis too
  (ff/heads/vocab over 256 ways where divisible) — decode activations are
  tiny so the extra psums are latency-trivial. **CONFIRMED**: memory term
  60.3 ms -> **3.86 ms/token (15.6x)**; compute term -14x (replicated math
  eliminated); collectives +16 ops (+10 us-scale latency).
* Remaining 3.9 ms is ~50% HLO copy inflation around the cache update
  (in-place on TPU) and ~0.5 ms true weight traffic: the est-model floor is
  ~0.9 ms/token => decode at >1k tok/s/pod for a 52B hybrid at 500k context.
* This sharding IS the paper's technique transplanted: spread the state so
  every sweep is bandwidth-local, pay only nearest-neighbor/reduction
  traffic (sequence-sharded flash-decode = partial softmax + AllReduce =
  the paper's Fig. 6 pattern).
* Upstreamed: the v2 rules are now jamba's and grok-1's config defaults
  (`ArchConfig.rules`, FSDP-style weight spreading) — they are also what
  makes the 314B/52B cells FIT 16 GB/chip at all (§Dry-run footprints).

### Stopping criterion

Three further candidates were napkin-mathed below the 5% threshold on their
cells' dominant terms (dispatch-mask dtype int8: ~1%; halo-width-2 double
buffering: <1% at these block sizes; remat policy tuning on train cells:
memory-term neutral, compute +8%), so the loop stops per the protocol.
""")

    A("""## §Precision (paper Fig. 9 + §VI-B, reproduced and extended)

`python -m benchmarks.run --only precision_residual` (convection-diffusion
momentum-like system, true f32 residuals):

| iteration | f32 | bf16-mixed |
|---|---|---|
| 1 | 1.54e-1 | 1.54e-1 |
| 7 | 2.48e-2 | 1.47e-2 |
| 16 | 1.60e-3 | 1.28e-2 |
| 34 | 9.8e-4 | 1.18e-2 (plateau) |

bf16-mixed tracks f32 to ~iteration 7 then plateaus at ~1.2e-2 — the same
shape and magnitude as the paper's fp16 Fig. 9 (their plateau 1e-2).
Beyond the paper: iterative refinement (f32 residuals, bf16-mixed inner
solves) recovers full accuracy: 1.16e-2 -> 2.1e-4 -> 4.6e-6 -> 1.1e-7 over
four outer solves at <10% extra traffic (`bicgstab.solve_refined`, tested).

## §Scale-out notes (beyond the dry-run)

* Fault tolerance: atomic manifest-gated checkpoints, async writes,
  deterministic (seed, step) data replay, restart-budgeted runner — all
  tested including injected mid-run failures and bit-identical replay
  (tests/test_substrate.py, examples/train_lm.py).
* Elasticity: checkpoints store logical arrays; restore reshards onto a
  different mesh (tested 8 -> 4 devices).
* Gradient compression: int8 + error feedback for the DP axis, convergence
  tested; applies when DP crosses pods (50 GB/s links).
""")

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(doc))
    print("wrote EXPERIMENTS.md", len("\n".join(doc)), "bytes")


if __name__ == "__main__":
    main()
