"""§Perf hillclimb driver: baseline -> optimized variants for the three
chosen cells, each a hypothesis -> change -> measure cycle (EXPERIMENTS.md
§Perf records the full log).

Chosen cells (from the baseline roofline table):
  1. stencil cs1_paper      — the paper's own technique (memory-bound)
  2. qwen2_moe train_4k     — most collective-bound cell (MoE dispatch)
  3. jamba long_500k        — worst roofline fraction (decode, batch=1)

Run:  PYTHONPATH=src python -m benchmarks.hillclimb [--cell stencil|moe|long]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json



HBM_BW = 819e9


def _save(name: str, rec: dict, out="results/hillclimb"):
    os.makedirs(out, exist_ok=True)
    with open(os.path.join(out, name + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    keys = ("t_compute_s", "t_memory_s", "t_collective_s", "t_bound_s",
            "n_collectives", "dominant")
    print(name, {k: rec.get(k) for k in keys})


def stencil_variants():
    """Iterate the memory/collective terms of the BiCGStab iteration down."""
    from repro.launch.dryrun import lower_stencil_cell
    from repro.core.perfmodel import allreduce_latency

    X, Y, Z = 608, 608, 1536
    pts_chip = X * Y * Z / 256

    # V0: paper-faithful — blocking AllReduce per dot, streamed halos
    rec = lower_stencil_cell("cs1_paper", False, fused=False, overlap=False)
    rec["variant"] = "v0_paper_faithful"
    rec["words_per_pt"] = 42
    _save("stencil_v0_paper", rec)

    # V1: fused reductions (3 sync points, 1 AllReduce each)
    rec = lower_stencil_cell("cs1_paper", False, fused=True, overlap=False)
    rec["variant"] = "v1_fused_reductions"
    rec["words_per_pt"] = 42
    _save("stencil_v1_fusedred", rec)

    # V2: + overlapped halos (face-patch form; interior hides the permutes)
    rec = lower_stencil_cell("cs1_paper", False, fused=True, overlap=True)
    rec["variant"] = "v2_overlap_halo"
    rec["words_per_pt"] = 42
    _save("stencil_v2_overlap", rec)

    # V3/V4: analytic schedule variants (Pallas fused sweeps, fp8 coeffs);
    # interpret-mode Pallas cannot surface VMEM fusion in CPU cost analysis,
    # so the memory term comes from the audited words/pt schedule
    # (kernels exist + are tested: repro/kernels/fused_iter, stencil7).
    for name, words, note in (
        ("v3_fused_sweeps", 31,
         "SpMV+dot epilogues, fused q/x/r/p updates (kernels/fused_iter)"),
        ("v4_fp8_coeffs", 25,
         "v3 + fp8(e4m3) coefficient diagonals (6 words -> 3 eq-words/SpMV)"),
    ):
        t_mem = words * 2 * pts_chip / HBM_BW
        rec = {
            "variant": name, "note": note, "words_per_pt": words,
            "t_memory_s": t_mem,
            "t_collective_s": 3 * allreduce_latency(16, 16),
            "t_bound_s": t_mem + 3 * allreduce_latency(16, 16),
            "analytic": True,
        }
        _save(f"stencil_{name}", rec)


def moe_variants():
    from repro.configs import get_config
    from repro.launch.dryrun import lower_lm_cell

    cfg = get_config("qwen2_moe_a2_7b")
    v0 = lower_lm_cell("qwen2_moe_a2_7b", "train_4k", False,
                       cfg=dataclasses.replace(cfg, moe_dispatch="scatter"))
    v0["variant"] = "v0_scatter_dispatch"
    _save("moe_v0_scatter", v0)

    v1 = lower_lm_cell("qwen2_moe_a2_7b", "train_4k", False,
                       cfg=dataclasses.replace(cfg, moe_dispatch="einsum"))
    v1["variant"] = "v1_einsum_dispatch"
    _save("moe_v1_einsum", v1)

    # v2: einsum dispatch + larger groups (fewer cumsum edges, same flops)
    v2 = lower_lm_cell("qwen2_moe_a2_7b", "train_4k", False,
                       cfg=dataclasses.replace(cfg, moe_dispatch="einsum",
                                               moe_group_size=4096))
    v2["variant"] = "v2_einsum_group4096"
    _save("moe_v2_group4096", v2)

    # v3: expert-data-parallel — groups spread over the model axis too,
    # expert weights replicated (qwen2-moe experts total ~1GB: affordable).
    # Kills the down-proj AllReduce AND cuts per-chip MoE flops 16x.
    from repro.models.param import rule_overrides
    with rule_overrides({"moe_groups": ("pod", "data", "model"),
                         "experts": None, "expert_ff": None}):
        v3 = lower_lm_cell("qwen2_moe_a2_7b", "train_4k", False,
                           cfg=dataclasses.replace(cfg, moe_dispatch="einsum"))
    v3["variant"] = "v3_expert_data_parallel"
    _save("moe_v3_edp", v3)


def long_variants():
    from repro.configs import get_config
    from repro.launch.dryrun import lower_lm_cell
    from repro.models.param import rule_overrides

    cfg = get_config("jamba_v0_1_52b")
    v0 = lower_lm_cell("jamba_v0_1_52b", "long_500k", False, cfg=cfg)
    v0["variant"] = "v0_baseline_rules"
    _save("long_v0_baseline", v0)

    with rule_overrides({"kv_seq": ("model", "data")}):
        v1 = lower_lm_cell("jamba_v0_1_52b", "long_500k", False, cfg=cfg)
    v1["variant"] = "v1_kv_over_data"
    _save("long_v1_kvdata", v1)

    with rule_overrides({
        "kv_seq": ("model", "data"),
        "ff": ("model", "data"), "expert_ff": ("model", "data"),
        "heads_flat": ("model", "data"), "vocab": ("model", "data"),
        "heads": ("model", "data"), "kv_heads": ("model", "data"),
    }):
        v2 = lower_lm_cell("jamba_v0_1_52b", "long_500k", False, cfg=cfg)
    v2["variant"] = "v2_weights_over_data_too"
    _save("long_v2_weightsdata", v2)


def run() -> list[str]:
    """benchmarks.run entry: the stencil hillclimb cells as CSV rows.

    Runs in a subprocess because the 512-device XLA_FLAGS fake fabric must
    be set before jax initializes — this module does that at import time,
    which is too late once benchmarks.run has imported jax.
    """
    import subprocess
    import sys

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=512",
               PYTHONPATH="src")
    subprocess.run(
        [sys.executable, "-m", "benchmarks.hillclimb", "--cell", "stencil"],
        check=True, env=env, capture_output=True, text=True)

    rows = []
    out = "results/hillclimb"
    for fn in sorted(os.listdir(out)):
        if not (fn.startswith("stencil_") and fn.endswith(".json")):
            continue
        with open(os.path.join(out, fn)) as f:
            rec = json.load(f)
        variant = rec.get("variant", fn[:-5])
        for k in ("t_memory_s", "t_collective_s", "t_bound_s"):
            if rec.get(k) is not None:
                rows.append(f"hillclimb,{variant}_{k},{rec[k]:.3e}")
        if rec.get("words_per_pt") is not None:
            rows.append(f"hillclimb,{variant}_words_per_pt,{rec['words_per_pt']}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=["stencil", "moe", "long", "all"],
                    default="all")
    args = ap.parse_args()
    if args.cell in ("stencil", "all"):
        stencil_variants()
    if args.cell in ("moe", "all"):
        moe_variants()
    if args.cell in ("long", "all"):
        long_variants()


if __name__ == "__main__":
    main()
