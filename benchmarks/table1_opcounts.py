"""Paper Table I: operations per meshpoint per BiCGStab iteration.

Validates the analytic counts (44 ops/pt: 24 matvec + 8 dot + 12 axpy)
against (a) this repo's op accounting and (b) the compiled HLO flops of one
distributed iteration (f32 twin; measured HLO/model ratio ~1.11 — the 11%
is `select`/`divide` scalar overhead and boundary patching).
"""

import json
import os

from repro.configs.stencil_cs1 import ops_per_meshpoint
from repro.core import stencil


def run() -> list[str]:
    t = ops_per_meshpoint()
    rows = []
    analytic = (2 * stencil.flops_per_point(3)        # 2 SpMV
                + 4 * 2                               # 4 dots: mul+add each
                + 6 * 2)                              # 6 AXPYs: mul+add each
    rows.append(f"table1,analytic_total_ops_per_pt,{analytic}")
    rows.append(f"table1,paper_total_ops_per_pt,{t['total']}")
    assert analytic == t["total"] == 44
    for k, v in t.items():
        rows.append(f"table1,{k},{v}")
    # compiled-HLO cross-check from the dry-run artifact (if present)
    path = "results/dryrun/cs1_paper__bicgstab_iter__pod1.json"
    if os.path.exists(path):
        r = json.load(open(path))
        hlo = r["per_chip_flops"] * r["n_devices"]
        model = 44.0 * r["meshpoints"]
        rows.append(f"table1,hlo_flops_over_model,{hlo / model:.4f}")
    return rows
