"""Stencil-family sweep: FLOP and collective accounting across shapes.

For each family member (star7, star13, star25, box27) this benchmark
reports, in one place, what changing the stencil shape costs:

* analytic per-meshpoint accounting (Table-I generalized): flops per SpMV,
  ops per BiCGStab iteration, halo depth and words moved per shard;
* measured HLO collective counts for ONE distributed iteration
  (``make_iteration_fn`` lowered on a 2x2 fake-device fabric in a
  subprocess): AllReduces with the fused vs paper-separate reduction
  schedule, and collective-permutes for the two halo-exchange SpMVs;
* a small end-to-end solve (iterations, residual, wall time, achieved
  FLOP/s on this host).

Emits ``name,metric,value`` CSV rows (the benchmarks/run.py contract) and
writes the full structured record to ``results/stencil_family.json`` —
see docs/benchmarks.md for the meaning of every JSON field.
"""

from __future__ import annotations

import json
import os
import time

SHAPES = ("star7", "star13", "star25", "box27")
SOLVE_SHAPE = (16, 16, 8)
_SUBPROC_DEVICES = 4

_COUNT_SNIPPET = """
    import json
    import jax, jax.numpy as jnp
    from repro.core import bicgstab, precision, stencil
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices({n})
    shape = {shape}
    out = {{}}
    for name in {shapes}:
        spec = stencil.get_spec(name)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
        structs = [jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), cf)]
        f32 = jax.ShapeDtypeStruct(shape, jnp.float32)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        structs += [f32, f32, f32, f32, scalar]
        counts = {{}}
        for fused in (True, False):
            it = bicgstab.make_iteration_fn(mesh, policy=precision.F32,
                                            fused_reductions=fused)
            text = jax.jit(it).lower(*structs).as_text()
            key = "fused" if fused else "separate"
            counts["allreduce_per_iter_" + key] = (
                text.count("all_reduce") + text.count("all-reduce"))
            if fused:
                counts["ppermute_per_iter"] = (
                    text.count("collective_permute") + text.count("collective-permute"))
        out[name] = counts
    print(json.dumps(out))
"""


def measure_collectives(shapes=SHAPES, n_devices: int = _SUBPROC_DEVICES,
                        shape=SOLVE_SHAPE) -> dict:
    """HLO collective-op counts per iteration, on a fake multi-device fabric.

    Runs in a subprocess because the fabric needs
    ``--xla_force_host_platform_device_count`` set before jax initializes.
    """
    from benchmarks._subproc import run_hlo_subprocess

    return run_hlo_subprocess(
        _COUNT_SNIPPET.format(n=n_devices, shape=tuple(shape),
                              shapes=tuple(shapes)),
        n_devices)


def sweep(shapes=SHAPES, *, measure_hlo: bool = True) -> dict:
    """The full sweep record (the contents of results/stencil_family.json)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import bicgstab, precision, stencil
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices()
    hlo = measure_collectives(shapes) if measure_hlo else {}
    npts = int(np.prod(SOLVE_SHAPE))
    # per-shard block on the 2x2 fabric the HLO collectives are measured on,
    # so the analytic halo words and the measured ppermute counts line up
    hlo_block = (SOLVE_SHAPE[0] // 2, SOLVE_SHAPE[1] // 2, SOLVE_SHAPE[2])
    cells = []
    for name in shapes:
        spec = stencil.get_spec(name)
        flops_spmv = stencil.spec_flops_per_point(spec)
        ops_iter = 2 * flops_spmv + 8 + 12          # 2 SpMV + 4 dots + 6 AXPYs
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), SOLVE_SHAPE,
                                         spec=spec)
        x_true = jax.random.normal(jax.random.PRNGKey(1), SOLVE_SHAPE,
                                   jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        t0 = time.time()
        res = bicgstab.solve_distributed(mesh, cf, b, tol=1e-6, maxiter=300,
                                         policy=precision.F32)
        jax.block_until_ready(res.x)
        wall = time.time() - t0
        iters = int(res.iterations)
        cells.append({
            "stencil": name,
            "pattern": spec.pattern,
            "radius": spec.radius,
            "n_points": spec.n_points,
            "halo_depth": spec.radius,
            "needs_corner_halo": spec.needs_corners,
            "flops_per_point_per_spmv": flops_spmv,
            "ops_per_point_per_iter": ops_iter,
            "words_per_point_per_spmv": stencil.spec_words_per_point(spec),
            "halo_words_per_spmv_per_shard": stencil.halo_words_per_spmv(
                spec, hlo_block),
            **hlo.get(name, {}),
            "solve": {
                "problem_shape": list(SOLVE_SHAPE),
                "iterations": iters,
                "converged": bool(res.converged),
                "rel_residual": float(res.rel_residual),
                "wall_s": wall,
                "achieved_flops_per_s": iters * ops_iter * npts / max(wall, 1e-9),
            },
        })
    return {
        "generated_by": "benchmarks/stencil_family.py",
        "schema": "repro.benchmark.v1",
        "solve_fabric": "x".join(str(s) for s in mesh.devices.shape),
        "hlo_fabric_devices": _SUBPROC_DEVICES if measure_hlo else 0,
        "cells": cells,
    }


def run() -> list[str]:
    record = sweep()
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "stencil_family.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    from repro.obs.manifest import write_benchmark_bundle
    bundle_dir = write_benchmark_bundle("stencil_family", record)
    rows = [f"stencil_family,json_path,{path}"]
    rows.append(f"stencil_family,run_bundle,{bundle_dir}")
    for c in record["cells"]:
        n = c["stencil"]
        rows.append(f"stencil_family,{n}_flops_per_pt_spmv,{c['flops_per_point_per_spmv']}")
        rows.append(f"stencil_family,{n}_ops_per_pt_iter,{c['ops_per_point_per_iter']}")
        rows.append(f"stencil_family,{n}_halo_depth,{c['halo_depth']}")
        if "allreduce_per_iter_fused" in c:
            rows.append(f"stencil_family,{n}_allreduce_fused,{c['allreduce_per_iter_fused']}")
            rows.append(f"stencil_family,{n}_allreduce_separate,{c['allreduce_per_iter_separate']}")
            rows.append(f"stencil_family,{n}_ppermute_per_iter,{c['ppermute_per_iter']}")
        s = c["solve"]
        assert s["converged"], f"{n} solve did not converge: {s}"
        rows.append(f"stencil_family,{n}_solve_iters,{s['iterations']}")
        rows.append(f"stencil_family,{n}_mflops,{s['achieved_flops_per_s'] / 1e6:.1f}")
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
