"""Paper Fig. 9: normwise relative residual, mixed vs 32-bit arithmetic.

The paper takes a momentum-equation system from MFIX on a 100x400x100 mesh;
mixed fp16/32 tracks fp32 until ~iteration 7, then plateaus near 1e-2 (their
fp16 machine precision ~1e-3 minus conditioning).  We reproduce the
experiment with the TPU-native bf16 policy on a convection-diffusion
momentum-like system (reduced mesh for CPU) measuring the TRUE residual
||b - Ax||/||b|| in f32 per iteration, and add the beyond-paper fix:
iterative refinement recovering f32 accuracy with a 16-bit inner solver.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bicgstab, precision, stencil


def _true_residual_curve(cf, b, policy, iters):
    """Run BiCGStab step by step, recording the true f32 residual."""
    cf32 = cf.astype(jnp.float32)
    bs = b.astype(policy.storage)
    res = bicgstab.solve_ref(cf, bs, tol=1e-30, maxiter=iters,
                             policy=policy, record_history=True)
    # recompute TRUE residuals by replaying x through history is costly;
    # instead run increasing-iteration solves (deterministic loop => same path)
    curve = []
    for i in range(1, iters + 1, max(1, iters // 12)):
        r = bicgstab.solve_ref(cf, bs, tol=1e-30, maxiter=i, policy=policy)
        rr = np.asarray(b, np.float64) - np.asarray(
            stencil.apply_ref(cf32, r.x.astype(jnp.float32)), np.float64)
        curve.append((i, float(np.linalg.norm(rr) /
                               np.linalg.norm(np.asarray(b, np.float64)))))
    return curve, res


def run() -> list[str]:
    rows = []
    # momentum-like system: strongly convective, nonsymmetric (paper §VI-B)
    shape = (24, 48, 24)   # reduced-aspect version of the paper's 100x400x100
    cf = stencil.convection_diffusion(shape, peclet=5.0)
    x_true = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)

    for policy in (precision.F32, precision.MIXED):
        curve, _ = _true_residual_curve(cf, b, policy, iters=36)
        for i, r in curve:
            rows.append(f"fig9,{policy.name}_iter{i:02d}_rel_residual,{r:.3e}")
        rows.append(f"fig9,{policy.name}_final,{curve[-1][1]:.3e}")

    # plateau check: mixed stalls >= ~1e-4 while f32 goes below 1e-5
    # beyond-paper: iterative refinement with bf16 inner solves
    x, rels = bicgstab.solve_refined(cf, b, outer_iters=4, inner_maxiter=40,
                                     inner_policy=precision.MIXED)
    for i, r in enumerate(np.asarray(rels)):
        rows.append(f"fig9,refined_outer{i}_rel_residual,{float(r):.3e}")
    return rows
