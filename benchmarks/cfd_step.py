"""Paper Table II split for the CFD application: % of one SIMPLE outer
iteration spent in the linear solves vs forming the matrices.

The paper reports MFIX spending 50-70% of its time in the (BiCGStab) linear
solver and most of the rest forming coefficients — the split that motivates
putting the whole application, not just the solve, on the fabric.  This
benchmark sweeps that split per {backend x preconditioner} cell through
``repro.apps.cfd.driver.measure_solve_share`` — the driver-level accounting
that times the full step end-to-end and a formation-only variant (same halo
gathers, same three systems, no solves), attributes the difference to the
solves, and lands the split in the observability registry
(``cfd.solve_share``/``cfd.form_share`` gauges) so every run reports it.

Emits ``results/cfd_step.json`` plus ``name,metric,value`` CSV rows
(the benchmarks/run.py contract).  ``--smoke`` shrinks the grid for CI.
"""

from __future__ import annotations

import argparse
import json
import os

CELLS = (("reference", "none"), ("reference", "jacobi"),
         ("spmd", "none"), ("spmd", "jacobi"))


def measure_cell(cfg, opts, mesh, state, reps: int) -> dict:
    from repro.apps.cfd.driver import measure_solve_share

    return measure_solve_share(cfg, opts, mesh, state, reps=reps)


def sweep(*, smoke: bool = False) -> dict:
    from repro.apps.cfd import CFDConfig, SolverOptions, make_step_fn
    from repro.apps.cfd.grid import cell_state
    from repro.launch.mesh import make_mesh_for_devices

    n = 16 if smoke else 32
    reps = 3 if smoke else 10
    cfg = CFDConfig(n=n, reynolds=100.0)
    mesh = make_mesh_for_devices()

    # measure on a partially developed flow, not the zero field
    u, v, p = cell_state(cfg)
    warm = make_step_fn(cfg, SolverOptions())
    for _ in range(5):
        u, v, p, _res, _m = warm(u, v, p, u, v)

    cells = []
    for backend, precond in CELLS:
        # raw rows so Jacobi preconditioning is real registry work, not a
        # no-op on pre-normalized coefficients
        opts = SolverOptions(backend=backend, precond=precond,
                             normalize=(precond == "none"))
        # the reference backend is single-address-space only
        cell_mesh = mesh if backend == "spmd" else None
        cells.append(measure_cell(cfg, opts, cell_mesh, (u, v, p), reps))
    return {
        "generated_by": "benchmarks/cfd_step.py",
        "schema": "repro.benchmark.v1",
        "smoke": smoke,
        "grid": [n, n],
        "inner_iters": {"momentum": cfg.inner_iters_mom,
                        "pressure": cfg.inner_iters_p},
        "fabric": "x".join(str(s) for s in mesh.devices.shape),
        "paper_table2": "MFIX: 50-70% of time in the linear solver",
        "cells": cells,
    }


def run(*, smoke: bool = False) -> list[str]:
    record = sweep(smoke=smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "cfd_step.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    from repro.obs.manifest import write_benchmark_bundle
    bundle_dir = write_benchmark_bundle("cfd_step", record)
    rows = [f"cfd_step,json_path,{path}"]
    rows.append(f"cfd_step,run_bundle,{bundle_dir}")
    for c in record["cells"]:
        tag = f"{c['backend']}_{c['precond']}"
        assert 0.0 < c["solve_pct"] < 100.0, f"degenerate split for {tag}: {c}"
        rows.append(f"cfd_step,{tag}_step_ms,{c['step_ms']:.1f}")
        rows.append(f"cfd_step,{tag}_solve_pct,{c['solve_pct']:.1f}")
        rows.append(f"cfd_step,{tag}_form_pct,{c['form_pct']:.1f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + few reps (CI)")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
