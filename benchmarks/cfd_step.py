"""Paper Table II split for the CFD application: % of one SIMPLE outer
iteration spent in the linear solves vs forming the matrices.

The paper reports MFIX spending 50-70% of its time in the (BiCGStab) linear
solver and most of the rest forming coefficients — the split that motivates
putting the whole application, not just the solve, on the fabric.  This
benchmark measures that split for this repo's SIMPLE implementation per
{backend x preconditioner} cell: the full step is timed end-to-end, a
formation-only variant (same halo gathers, same three systems, no solves)
is timed separately, and the difference is attributed to the solves.

Emits ``results/cfd_step.json`` plus ``name,metric,value`` CSV rows
(the benchmarks/run.py contract).  ``--smoke`` shrinks the grid for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time

CELLS = (("reference", "none"), ("reference", "jacobi"),
         ("spmd", "none"), ("spmd", "jacobi"))


def _time_fn(fn, args, reps: int) -> float:
    import jax

    jax.block_until_ready(fn(*args))          # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def measure_cell(cfg, opts, mesh, state, reps: int) -> dict:
    from repro.apps.cfd import make_step_fn

    u, v, p = state
    step = make_step_fn(cfg, opts, mesh)
    form = make_step_fn(cfg, opts, mesh, form_only=True)
    t_full = _time_fn(step, (u, v, p, u, v), reps)
    t_form = _time_fn(form, (u, v, p, u, v), reps)
    t_solve = max(t_full - t_form, 0.0)
    return {
        "backend": opts.backend,
        "precond": (opts.precond if isinstance(opts.precond, str)
                    else opts.precond.name),
        "rows": "unit-diagonal" if opts.normalize else "raw",
        "step_ms": t_full * 1e3,
        "form_ms": t_form * 1e3,
        "solve_ms": t_solve * 1e3,
        "solve_pct": 100.0 * t_solve / t_full,
        "form_pct": 100.0 * t_form / t_full,
    }


def sweep(*, smoke: bool = False) -> dict:
    from repro.apps.cfd import CFDConfig, SolverOptions, make_step_fn
    from repro.apps.cfd.grid import cell_state
    from repro.launch.mesh import make_mesh_for_devices

    n = 16 if smoke else 32
    reps = 3 if smoke else 10
    cfg = CFDConfig(n=n, reynolds=100.0)
    mesh = make_mesh_for_devices()

    # measure on a partially developed flow, not the zero field
    u, v, p = cell_state(cfg)
    warm = make_step_fn(cfg, SolverOptions())
    for _ in range(5):
        u, v, p, _res, _m = warm(u, v, p, u, v)

    cells = []
    for backend, precond in CELLS:
        # raw rows so Jacobi preconditioning is real registry work, not a
        # no-op on pre-normalized coefficients
        opts = SolverOptions(backend=backend, precond=precond,
                             normalize=(precond == "none"))
        # the reference backend is single-address-space only
        cell_mesh = mesh if backend == "spmd" else None
        cells.append(measure_cell(cfg, opts, cell_mesh, (u, v, p), reps))
    return {
        "generated_by": "benchmarks/cfd_step.py",
        "smoke": smoke,
        "grid": [n, n],
        "inner_iters": {"momentum": cfg.inner_iters_mom,
                        "pressure": cfg.inner_iters_p},
        "fabric": "x".join(str(s) for s in mesh.devices.shape),
        "paper_table2": "MFIX: 50-70% of time in the linear solver",
        "cells": cells,
    }


def run(*, smoke: bool = False) -> list[str]:
    record = sweep(smoke=smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "cfd_step.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    rows = [f"cfd_step,json_path,{path}"]
    for c in record["cells"]:
        tag = f"{c['backend']}_{c['precond']}"
        assert 0.0 < c["solve_pct"] < 100.0, f"degenerate split for {tag}: {c}"
        rows.append(f"cfd_step,{tag}_step_ms,{c['step_ms']:.1f}")
        rows.append(f"cfd_step,{tag}_solve_pct,{c['solve_pct']:.1f}")
        rows.append(f"cfd_step,{tag}_form_pct,{c['form_pct']:.1f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid + few reps (CI)")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
