"""Paper §V: time per BiCGStab iteration (28.1 us on CS-1, 600x595x1536).

Two views:
1. Roofline-model prediction for the TPU target (from the dry-run artifact):
   per-iteration bound = max(compute, memory, collective) terms.
2. Measured CPU wall-clock per iteration at a reduced mesh (sanity anchor —
   the container is CPU-only).
"""

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import bicgstab, precision, stencil
from repro.launch.mesh import make_mesh_for_devices


def run() -> list[str]:
    rows = []
    for tag, mesh_name in (("pod1", "16x16 (256 chips)"),
                           ("pod2", "2x16x16 (512 chips)")):
        path = f"results/dryrun/cs1_paper__bicgstab_iter__{tag}.json"
        if not os.path.exists(path):
            continue
        r = json.load(open(path))
        us = r["t_bound_s"] * 1e6
        rows.append(f"iter_time,tpu_roofline_{tag}_us,{us:.1f}")
        rows.append(f"iter_time,tpu_dominant_{tag},{r['dominant']}")
    rows.append("iter_time,cs1_paper_us,28.1")

    # measured CPU anchor at reduced scale
    shape = (32, 32, 64)
    cf = stencil.convection_diffusion(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    mesh = make_mesh_for_devices()
    solve = jax.jit(lambda c, bb: bicgstab.solve_distributed(
        mesh, c, bb, tol=1e-30, maxiter=50, policy=precision.F32))
    res = solve(cf, b)
    jax.block_until_ready(res.x)  # compile+warm
    t0 = time.time()
    res = solve(cf, b)
    jax.block_until_ready(res.x)
    dt = time.time() - t0
    us_per_iter = dt / max(int(res.iterations), 1) * 1e6
    rows.append(f"iter_time,cpu_measured_{shape[0]}x{shape[1]}x{shape[2]}_us,"
                f"{us_per_iter:.0f}")
    return rows
