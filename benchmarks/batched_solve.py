"""Batched (many-RHS) solve sweep: solves/sec vs batch size B.

The batched solver stack amortizes the fabric's fixed per-iteration costs
across B right-hand sides: each halo ppermute carries the (B, r, ...) slabs
of every RHS in one message, and each sync point reduces the stacked
``[k, B]`` partials in ONE AllReduce — so the collective count per
iteration is independent of B while the useful work scales linearly.  On a
latency-bound fabric (the regime the paper's CS-1 erases and commodity
fabrics live in) that makes block solves the cheapest way to buy
throughput: solves/sec should rise monotonically with B until compute,
not latency, saturates.

This benchmark measures exactly that, in one JSON
(``results/batched_solve.json``):

* ``matrix`` — jitted distributed solves of the ``batched_poisson`` config
  cell for B in the sweep x {bicgstab, pipelined_bicgstab}, at ``tol=0``
  with a fixed ``maxiter`` so every batch size times an *identical*
  iteration count (pure throughput, no convergence luck): wall clock,
  solves/sec (= B / wall, best of 3), iterations.
* ``collectives`` — HLO totals for the whole jitted solve on a fake 2x2
  fabric, asserted: the AllReduce count per iteration is the same for
  B=1 and B>1 (1 for pipelined_bicgstab, 3 for fused bicgstab), and the
  ppermute count does not grow with B.

Asserted on the smoke cell (multi-device fabrics — the CI invocation runs
under ``scripts/run.sh``'s 8 fake devices): solves/sec strictly increases
from B=1 to B=8 — fixed per-iteration dispatch/collective overhead
dominates the tiny cell, so batching must win or the batch axis is broken.

Emits ``name,metric,value`` CSV rows (the benchmarks/run.py contract).
``--smoke`` shrinks the sweep for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks._subproc import run_hlo_subprocess

BATCH_SIZES = (1, 2, 4, 8, 16)
SMOKE_BATCH_SIZES = (1, 2, 4, 8)
SOLVERS = ("bicgstab", "pipelined_bicgstab")
MAXITER = 12
_SUBPROC_DEVICES = 4

_COLLECTIVE_SNIPPET = """
    import json
    import jax, jax.numpy as jnp
    from repro.core import bicgstab, precision, stencil
    from repro.launch.mesh import make_mesh_for_devices
    from repro.obs.metrics import count_collectives

    mesh = make_mesh_for_devices({n})
    shape = {shape}
    cf = stencil.poisson(shape)
    out = {{}}
    for solver in ("bicgstab", "pipelined_bicgstab"):
        counts = {{}}
        for B in (1, 4):
            b = jnp.ones((B,) + shape, jnp.float32)
            f = lambda c, bb: bicgstab.solve_distributed(
                mesh, c, bb, tol=0.0, maxiter=8, policy=precision.F32,
                solver=solver, schedule="overlap")
            counts[f"B{{B}}"] = count_collectives(
                jax.jit(f).lower(cf, b).as_text())
        # setup dots fold into ONE reduction; the loop body is emitted once
        counts["allreduce_per_iter"] = counts["B1"]["allreduce_total"] - 1
        out[solver] = counts
    print(json.dumps(out))
"""

PER_ITER_WANT = {"bicgstab": 3, "pipelined_bicgstab": 1}


def measure_collectives(shape, n_devices: int = _SUBPROC_DEVICES) -> dict:
    """Whole-solve HLO collective totals per {solver x B} on a fake 2x2
    fabric (subprocess: the device count must precede jax init).

    The batched-schedule claims are asserted here, on the structured
    counts — not buried inline in the measurement snippet — and mirrored
    into the observability registry; the CI schema-validation step makes
    the same batch-invariance assertion against `--obs` run bundles.
    """
    from repro.obs import metrics as obs_metrics

    out = run_hlo_subprocess(
        _COLLECTIVE_SNIPPET.format(n=n_devices, shape=tuple(shape)),
        n_devices)
    for solver, counts in out.items():
        assert counts["allreduce_per_iter"] == PER_ITER_WANT[solver], (
            solver, counts)
        # THE batched-schedule claim: collectives are B-independent
        assert counts["B4"] == counts["B1"], (solver, counts)
        obs_metrics.event("collectives_batch_invariance", solver=solver,
                          **counts)
    return out


def sweep(*, smoke: bool = False, measure_hlo: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs.stencil_star25_seismic import BATCHED_CELLS
    from repro.core import bicgstab, precision, stencil
    from repro.launch.mesh import make_mesh_for_devices

    cell = BATCHED_CELLS["batched_poisson"]
    mesh = make_mesh_for_devices()
    shape = (12, 12, 8) if smoke else cell.mesh_shape
    batches = SMOKE_BATCH_SIZES if smoke else BATCH_SIZES
    pol = precision.get_policy(cell.policy)
    spec = stencil.get_spec(cell.stencil)
    cf = stencil.poisson(shape, spec=spec)

    cells = []
    for solver in SOLVERS:
        for B in batches:
            x_true = jax.random.normal(jax.random.PRNGKey(1), (B,) + shape,
                                       jnp.float32)
            b = stencil.rhs_for_solution(cf, x_true).astype(pol.storage)
            # tol=0 + fixed maxiter: every B times the SAME iteration count
            solve = jax.jit(lambda c, bb, solver=solver:
                            bicgstab.solve_distributed(
                                mesh, c, bb, tol=0.0, maxiter=MAXITER,
                                policy=pol, solver=solver,
                                schedule=cell.schedule, backend=cell.backend))
            res = solve(cf, b)
            jax.block_until_ready(res.x)          # compile + warm
            wall = float("inf")
            for _ in range(3):
                t0 = time.time()
                res = solve(cf, b)
                jax.block_until_ready(res.x)
                wall = min(wall, time.time() - t0)
            iters = int(jax.numpy.max(res.iterations))
            cells.append({
                "solver": solver, "nrhs": B,
                "problem_shape": list(shape),
                "maxiter": MAXITER, "iterations": iters,
                "wall_s": wall,
                "solves_per_sec": B / wall,
                "us_per_iter": wall / max(iters, 1) * 1e6,
            })

    record = {
        "generated_by": "benchmarks/batched_solve.py",
        "schema": "repro.benchmark.v1",
        "smoke": smoke,
        "cell": cell.name,
        "n_devices": int(mesh.devices.size),
        "solve_fabric": "x".join(str(s) for s in mesh.devices.shape),
        "batch_sizes": list(batches),
        "matrix": cells,
    }
    if measure_hlo:
        record["collectives"] = measure_collectives(shape)
        record["hlo_fabric_devices"] = _SUBPROC_DEVICES
    return record


def run(*, smoke: bool = False) -> list[str]:
    record = sweep(smoke=smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "batched_solve.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    from repro.obs.manifest import write_benchmark_bundle
    bundle_dir = write_benchmark_bundle("batched_solve", record)
    rows = [f"batched_solve,json_path,{path}"]
    rows.append(f"batched_solve,run_bundle,{bundle_dir}")
    for solver in SOLVERS:
        sps = {c["nrhs"]: c["solves_per_sec"] for c in record["matrix"]
               if c["solver"] == solver}
        for B in sorted(sps):
            rows.append(f"batched_solve,{solver}_B{B}_solves_per_sec,"
                        f"{sps[B]:.2f}")
        # the amortization claim, end to end: batching strictly buys
        # throughput on the latency-dominated smoke/default cell.  The
        # claim is about amortizing *collectives*, so it is asserted only
        # on a real (multi-device) fabric — a bare 1-device run (no
        # run.sh, no fake-device fabric) has nothing to amortize and is
        # reported but not asserted.
        ladder = [sps[B] for B in sorted(b for b in sps if b <= 8)]
        increasing = all(a < b for a, b in zip(ladder, ladder[1:]))
        if record["n_devices"] > 1:
            assert increasing, (
                f"{solver}: solves/sec not strictly increasing B=1..8: {sps}")
        elif not increasing:
            print(f"# note: {solver} ladder not monotonic on a 1-device "
                  f"fabric (nothing to amortize): {sps}")
    if "collectives" in record:
        for solver, counts in sorted(record["collectives"].items()):
            rows.append(f"batched_solve,{solver}_allreduce_per_iter,"
                        f"{counts['allreduce_per_iter']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (CI): B in {1,2,4,8} on a 12x12x8 cell")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
