"""Kernel-autotune sweep: block shapes x Z-splits x residency x ring fusion
per {StencilSpec x dtype x local shape}, persisted to the tuning cache.

The hypothesis -> measure loop of ``benchmarks/hillclimb.py`` turned into a
production autotuner: for each cell the harness times the fused Pallas
stencil kernel (``core/tuning.measure_config``) across the candidate
configs (``core/tuning.candidate_configs``), picks the winner, and persists
it to ``results/tuning_cache.json`` — after which every
``make_operator(backend="pallas")`` on that cell transparently uses the
tuned shapes.  A second run is a pure cache lookup: no re-sweep, identical
winners (``--force`` re-sweeps).

Reports per cell, CSV + ``results/kernel_autotune.json``:

* ``default_us`` / ``best_us`` / ``speedup`` — the fixed pre-tuning
  default (full-block tile, VMEM-budgeted Z chunk, split ring epilogue)
  vs the swept winner, measured under the same harness;
* ``roofline_frac_default`` / ``roofline_frac_tuned`` — SpMV bytes moved
  over measured time, as a fraction of the modeled per-chip peak
  (``tuning.PEAK_BYTES_PER_S`` — the hillclimb HBM figure, so the tables
  compare); the paper's ~1/3-of-peak is the bar;
* ``tuned_wins_frac`` — the fraction of swept cells where the tuned
  config strictly beats the fixed default (asserted >= 0.5 on fresh
  full sweeps).

Run:  PYTHONPATH=src python -m benchmarks.kernel_autotune [--smoke] [--force]

Pinned env: this harness measures single-process kernel wall time only —
run it through ``scripts/run.sh`` for the known-good malloc/XLA flags when
comparing numbers across machines.
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp

#: the swept cell matrix: {spec x dtype x local shape}.  Shapes are
#: per-shard local blocks (what the pallas backend looks up), sized so the
#: sweep finishes in minutes in interpret mode while leaving the tuner
#: real headroom over the fixed default.
CELLS = (
    ("star7", "float32", (32, 32, 64)),
    ("star7", "bfloat16", (32, 32, 64)),
    ("star7", "float32", (30, 35, 49)),   # odd extents: divisor candidates
    ("star25", "float32", (24, 24, 32)),
    ("box27", "float32", (16, 16, 32)),
    ("box27", "bfloat16", (16, 16, 32)),
)

SMOKE_CELLS = (
    ("star7", "float32", (16, 16, 16)),
    ("box27", "float32", (8, 8, 8)),
)


def sweep(*, smoke: bool = False, force: bool = False,
          repeats: int = 3) -> dict:
    from repro.core import stencil, tuning

    cells = SMOKE_CELLS if smoke else CELLS
    records = []
    for specname, dtype_name, shape in cells:
        spec = stencil.get_spec(specname)
        dtype = jnp.dtype(dtype_name)
        rec = tuning.autotune_cell(spec, dtype, shape, smoke=smoke,
                                   force=force, repeats=repeats)
        records.append(rec)

    fresh = [r for r in records if not r["cache_hit"]]
    wins = [r for r in fresh if r["speedup_vs_default"] > 1.0]
    record = {
        "generated_by": "benchmarks/kernel_autotune.py",
        "schema": "repro.benchmark.v1",
        "smoke": smoke,
        "cache_path": tuning.resolve_cache_path(),
        "peak_bytes_per_s": tuning.PEAK_BYTES_PER_S,
        "n_cells": len(records),
        "n_swept": len(fresh),
        "n_cache_hits": len(records) - len(fresh),
        "tuned_wins_frac": (len(wins) / len(fresh)) if fresh else None,
        "cells": records,
    }
    return record


def run(*, smoke: bool = False, force: bool = False) -> list[str]:
    record = sweep(smoke=smoke, force=force)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "kernel_autotune.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    from repro.obs.manifest import write_benchmark_bundle
    bundle_dir = write_benchmark_bundle("kernel_autotune", record)
    rows = [f"kernel_autotune,json_path,{path}",
            f"kernel_autotune,cache_path,{record['cache_path']}",
            f"kernel_autotune,n_cache_hits,{record['n_cache_hits']}"]
    rows.append(f"kernel_autotune,run_bundle,{bundle_dir}")
    for c in record["cells"]:
        tag = c["key"].replace("/", "_")
        rows.append(f"kernel_autotune,{tag}_cache_hit,{int(c['cache_hit'])}")
        rows.append(f"kernel_autotune,{tag}_default_us,"
                    f"{c['default_seconds'] * 1e6:.0f}")
        rows.append(f"kernel_autotune,{tag}_best_us,"
                    f"{c['best_seconds'] * 1e6:.0f}")
        rows.append(f"kernel_autotune,{tag}_speedup,"
                    f"{c['speedup_vs_default']:.3f}")
        rows.append(f"kernel_autotune,{tag}_roofline_frac_default,"
                    f"{c['roofline_frac_default']:.3e}")
        rows.append(f"kernel_autotune,{tag}_roofline_frac_tuned,"
                    f"{c['roofline_frac_tuned']:.3e}")
        cfg = c["config"]
        rows.append(f"kernel_autotune,{tag}_winner,"
                    f"{cfg['block'][0]}x{cfg['block'][1]}x{cfg['zc']}"
                    f"{'_fused' if cfg['fuse_ring'] else '_split'}")
    if record["tuned_wins_frac"] is not None:
        rows.append(f"kernel_autotune,tuned_wins_frac,"
                    f"{record['tuned_wins_frac']:.2f}")
        if not smoke:
            # acceptance gate: the sweep must actually pay for itself
            assert record["tuned_wins_frac"] >= 0.5, record["tuned_wins_frac"]
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cell matrix + reduced candidates (CI)")
    ap.add_argument("--force", action="store_true",
                    help="re-sweep cells that already have cache entries")
    args = ap.parse_args()
    for row in run(smoke=args.smoke, force=args.force):
        print(row)
