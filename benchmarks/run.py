"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,metric,value`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slowest measurements")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args()

    from benchmarks import (allreduce_model, batched_solve, cfd_step,
                            comm_overlap, hillclimb, iteration_time,
                            kernel_autotune, precision_residual,
                            roofline_report, simple_step, solver_matrix,
                            stencil_family, strong_scaling, table1_opcounts)

    benches = {
        "table1_opcounts": table1_opcounts.run,
        "allreduce_model": allreduce_model.run,
        "roofline_report": roofline_report.run,
        "iteration_time": iteration_time.run,
        "precision_residual": precision_residual.run,
        "stencil_family": stencil_family.run,
        "solver_matrix": solver_matrix.run,
        "comm_overlap": comm_overlap.run,
        "batched_solve": batched_solve.run,
        "kernel_autotune": kernel_autotune.run,
        "hillclimb": hillclimb.run,
        "simple_step": simple_step.run,
        "cfd_step": cfd_step.run,
        "strong_scaling": strong_scaling.run,
    }
    if args.fast:
        benches.pop("strong_scaling")
        benches.pop("simple_step")
        benches.pop("hillclimb")  # subprocess re-lowers the full cell matrix
        benches["cfd_step"] = lambda: cfd_step.run(smoke=True)
        benches["comm_overlap"] = lambda: comm_overlap.run(smoke=True)
        benches["batched_solve"] = lambda: batched_solve.run(smoke=True)
        benches["kernel_autotune"] = lambda: kernel_autotune.run(smoke=True)
    if args.only:
        benches = {args.only: benches[args.only]}

    failed = []
    for name, fn in benches.items():
        t0 = time.time()
        try:
            for row in fn():
                print(row)
            print(f"{name},bench_wall_s,{time.time() - t0:.1f}")
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name},ERROR,{type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
