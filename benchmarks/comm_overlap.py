"""Communication-scheduling sweep: {solver x schedule x stencil}.

The schedule is the one lever this repo controls on a commodity fabric
(paper §IV: the CS-1 wins because halo transfers and the AllReduce cost
~nothing; we must *hide* them instead).  This benchmark measures what each
scheduling choice does, in one JSON (``results/comm_overlap.json``):

* ``matrix`` — end-to-end distributed solves for every registered solver
  crossed with {blocking, overlap} halo schedules over the stencil shapes:
  iterations, wall clock per iteration, converged flag.  CG-family solvers
  get the symmetric Poisson operator, BiCGStab-family the nonsymmetric one;
  problem kind and tolerance follow ``solver_matrix.solver_problem_kind``
  / ``solver_tol`` so the two sweeps stay like-for-like (only pipelined_cg
  runs at its f32 attainable-accuracy floor, 1e-5 — see
  ``core/solvers/pipelined.py``).
* ``collectives`` — HLO totals for one whole jitted solve on a fake 2x2
  fabric: AllReduce count (asserted: setup 1 + per-iteration count from
  ``perfmodel.SOLVER_COMMS`` — exactly 1/iter for the pipelined solvers)
  and collective-permute count (asserted: schedule-independent — overlap
  changes *when* halos move, never how many messages).
* ``model`` — ``perfmodel.predict_crossover`` on the paper's 608x608x1536
  mesh: the fabric size where the pipelined single-reduction schedule
  overtakes the 3-AllReduce fused schedule, and where overlap overtakes
  blocking halos.

Emits ``name,metric,value`` CSV rows (the benchmarks/run.py contract).
``--smoke`` shrinks the matrix for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks._subproc import run_hlo_subprocess
from benchmarks.solver_matrix import solver_problem_kind, solver_tol

SHAPES = ("star7", "box27")
SOLVE_SHAPE = (16, 16, 8)
_SUBPROC_DEVICES = 4

_COLLECTIVE_SNIPPET = """
    import json
    import jax, jax.numpy as jnp
    from repro.core import bicgstab, precision, stencil
    from repro.core.perfmodel import SOLVER_COMMS
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices({n})
    shape = {shape}
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
    b = jnp.ones(shape, jnp.float32)
    out = {{}}
    for solver, comm in sorted(SOLVER_COMMS.items()):
        counts = {{}}
        for schedule in ("blocking", "overlap"):
            f = lambda c, bb: bicgstab.solve_distributed(
                mesh, c, bb, maxiter=8, policy=precision.F32,
                solver=solver, schedule=schedule)
            text = jax.jit(f).lower(cf, b).as_text()
            n_ar = text.count("all_reduce") + text.count("all-reduce")
            n_pp = (text.count("collective_permute")
                    + text.count("collective-permute"))
            counts[schedule] = {{"allreduce_total": n_ar, "ppermute_total": n_pp}}
        # every solver folds its setup dots into ONE reduction; the rest
        # is the loop body, emitted once in HLO
        per_iter = counts["overlap"]["allreduce_total"] - 1
        assert per_iter == comm.reductions_fused, (solver, per_iter)
        assert (counts["overlap"]["ppermute_total"]
                == counts["blocking"]["ppermute_total"]), (solver, counts)
        counts["allreduce_per_iter"] = per_iter
        out[solver] = counts
    print(json.dumps(out))
"""


def measure_collectives(shape=SOLVE_SHAPE,
                        n_devices: int = _SUBPROC_DEVICES) -> dict:
    """Whole-solve HLO collective totals per {solver x schedule} on a fake
    2x2 fabric (subprocess: the device count must precede jax init)."""
    return run_hlo_subprocess(
        _COLLECTIVE_SNIPPET.format(n=n_devices, shape=tuple(shape)),
        n_devices)


def sweep(*, smoke: bool = False, measure_hlo: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import bicgstab, precision, stencil
    from repro.core.perfmodel import SOLVER_COMMS, predict_crossover
    from repro.core.solvers import SOLVERS
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices()
    shape = (12, 12, 8) if smoke else SOLVE_SHAPE
    shapes = ("star7",) if smoke else SHAPES
    solvers = (("bicgstab", "pipelined_bicgstab") if smoke
               else tuple(sorted(SOLVERS)))
    pol = precision.F32

    cells = []
    for name in shapes:
        spec = stencil.get_spec(name)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        for solver in solvers:
            # shared problem-kind/tolerance rules with solver_matrix.py, so
            # the two sweeps stay like-for-like comparable
            problem = solver_problem_kind(solver)
            if problem == "poisson":
                cf = stencil.poisson(shape, spec=spec)
            else:
                cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0),
                                                 shape, spec=spec)
            b = stencil.rhs_for_solution(cf, x_true)
            tol = solver_tol(solver)
            for schedule in ("blocking", "overlap"):
                solve = jax.jit(lambda c, bb, solver=solver, schedule=schedule:
                                bicgstab.solve_distributed(
                                    mesh, c, bb, tol=tol, maxiter=400,
                                    policy=pol, solver=solver,
                                    schedule=schedule))
                res = solve(cf, b)
                jax.block_until_ready(res.x)      # compile + warm
                t0 = time.time()
                res = solve(cf, b)
                jax.block_until_ready(res.x)
                wall = time.time() - t0
                iters = int(res.iterations)
                err = float(np.abs(np.asarray(res.x, np.float64)
                                   - np.asarray(x_true, np.float64)).max())
                cells.append({
                    "stencil": name, "solver": solver, "schedule": schedule,
                    "problem": problem,
                    "problem_shape": list(shape), "tol": tol,
                    "iterations": iters,
                    "converged": bool(res.converged),
                    "breakdown": bool(res.breakdown),
                    "rel_residual": float(res.rel_residual),
                    "max_err": err,
                    "wall_s": wall,
                    "us_per_iter": wall / max(iters, 1) * 1e6,
                })

    model = {
        "mesh": [608, 608, 1536],
        "pipelined_bicgstab_vs_bicgstab": predict_crossover(
            (608, 608, 1536), {"solver": "bicgstab"},
            {"solver": "pipelined_bicgstab"}),
        "overlap_vs_blocking": predict_crossover(
            (608, 608, 1536), {"schedule": "blocking"},
            {"schedule": "overlap"}),
    }

    record = {
        "generated_by": "benchmarks/comm_overlap.py",
        "schema": "repro.benchmark.v1",
        "smoke": smoke,
        "solve_fabric": "x".join(str(s) for s in mesh.devices.shape),
        "solver_comms": {k: dataclass_dict(v)
                         for k, v in sorted(SOLVER_COMMS.items())},
        "matrix": cells,
        "model": model,
    }
    if measure_hlo:
        record["collectives"] = measure_collectives()
        record["hlo_fabric_devices"] = _SUBPROC_DEVICES
    return record


def dataclass_dict(dc) -> dict:
    import dataclasses
    return dataclasses.asdict(dc)


def run(*, smoke: bool = False) -> list[str]:
    record = sweep(smoke=smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "comm_overlap.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    from repro.obs.manifest import write_benchmark_bundle
    bundle_dir = write_benchmark_bundle("comm_overlap", record)
    rows = [f"comm_overlap,json_path,{path}"]
    rows.append(f"comm_overlap,run_bundle,{bundle_dir}")
    for c in record["matrix"]:
        tag = f"{c['stencil']}_{c['solver']}_{c['schedule']}"
        assert c["converged"], f"cell {tag} did not converge: {c}"
        rows.append(f"comm_overlap,{tag}_iters,{c['iterations']}")
        rows.append(f"comm_overlap,{tag}_us_per_iter,{c['us_per_iter']:.0f}")
    if "collectives" in record:
        for solver, counts in sorted(record["collectives"].items()):
            rows.append(f"comm_overlap,{solver}_allreduce_per_iter,"
                        f"{counts['allreduce_per_iter']}")
    m = record["model"]
    rows.append(f"comm_overlap,model_pipelined_crossover_chips,"
                f"{m['pipelined_bicgstab_vs_bicgstab']['crossover_chips']}")
    rows.append(f"comm_overlap,model_overlap_crossover_chips,"
                f"{m['overlap_vs_blocking']['crossover_chips']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny matrix (CI): star7 + 2 solvers, minutes")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
