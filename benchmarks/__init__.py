"""Benchmark harness: one module per paper table/figure + the roofline report.

Run everything:  PYTHONPATH=src python -m benchmarks.run
"""
