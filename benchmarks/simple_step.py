"""Paper Table II / §VI-A: SIMPLE cost outside the linear solver, and the
projected timesteps/second for the 600^3 MFIX case.

Measured: CPU wall time per SIMPLE outer iteration of the lid-driven cavity
(this repo's Alg. 2 implementation), split solver vs forming by timing a
forming-only variant.  Projected: the perfmodel's timesteps/s for the
TPU mesh (paper projects 80-125 on CS-1).
"""

import time

import jax
import jax.numpy as jnp

from repro.apps.cfd import CavityConfig, simple_step
from repro.core.perfmodel import mfix_timesteps_per_second


def run() -> list[str]:
    rows = []
    cfg = CavityConfig(n=32, reynolds=100.0)
    n = cfg.n
    u = jnp.zeros((n + 1, n)); v = jnp.zeros((n, n + 1)); p = jnp.zeros((n, n))
    import functools
    step = jax.jit(functools.partial(simple_step, cfg))
    u, v, p, r, aux = step(u, v, p)          # compile
    jax.block_until_ready(p)
    t0 = time.time()
    for _ in range(10):
        u, v, p, r, aux = step(u, v, p)
    jax.block_until_ready(p)
    rows.append(f"simple,cpu_outer_iter_ms_{n}sq,{(time.time()-t0)/10*1e3:.1f}")
    rows.append(f"simple,continuity_residual_after_11,{float(r):.3e}")

    for chips in (256, 512):
        tps = mfix_timesteps_per_second((608, 608, 608), chips)
        rows.append(f"simple,tpu_projected_600cube_timesteps_per_s_{chips}chips,"
                    f"{tps:.1f}")
    rows.append("simple,cs1_projected_timesteps_per_s,80-125")
    return rows
