"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON artifacts (results/dryrun/*.json)."""

import glob
import json
import os


def load_cells(out_dir: str = "results/dryrun") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def roofline_table(cells: list[dict], mesh: str = "16x16") -> str:
    hdr = ("| cell | t_comp (s) | t_mem hlo (s) | t_mem est (s) | t_coll (s) | "
           "dominant | MODEL/HLO flops | roofline frac (est) | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    levers = {
        "compute": "reduce replicated math (shard heads/seq) or raise per-chip batch",
        "memory": "fuse sweeps / shrink state dtype / raise arithmetic intensity",
        "collective": "batch or overlap reductions; reshard to cut all-to-all",
    }
    for r in sorted(cells, key=lambda c: (c["arch"], c["shape"])):
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        dom = r.get("dominant_est", r["dominant"])
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r.get('t_memory_est_s', 0):.2e} "
            f"| {r['t_collective_s']:.2e} | {dom} "
            f"| {r.get('useful_flops_ratio', 0):.3f} "
            f"| {r.get('roofline_fraction_est', r.get('roofline_fraction', 0)):.4f} "
            f"| {levers[dom]} |")
    return "\n".join(lines)


def dryrun_table(cells: list[dict]) -> str:
    hdr = ("| cell | mesh | status | compile (s) | args/chip | temps/chip | "
           "collectives | est footprint | fits 16GB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']}/{r['shape']} | {r['mesh']} | SKIP "
                         f"({r['skip_reason'][:48]}...) | | | | | | |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']}/{r['shape']} | {r['mesh']} | ERROR | | | | | | |")
            continue
        m = r["memory_analysis"]
        fits = r.get("est_fits_16gb", "")
        lines.append(
            f"| {r['arch']}/{r['shape']} | {r['mesh']} | ok "
            f"| {r.get('lower_compile_s', 0):.0f} "
            f"| {fmt_bytes(m['argument_size_in_bytes'])} "
            f"| {fmt_bytes(m['temp_size_in_bytes'])} "
            f"| {r.get('n_collectives', '')} "
            f"| {fmt_bytes(r.get('est_footprint_bytes', 0))} "
            f"| {fits} |")
    return "\n".join(lines)


def run() -> list[str]:
    cells = load_cells()
    ok = sum(c.get("status") == "ok" for c in cells)
    skip = sum(c.get("status") == "skipped" for c in cells)
    err = len(cells) - ok - skip
    rows = [f"roofline,cells_ok,{ok}", f"roofline,cells_skipped,{skip}",
            f"roofline,cells_error,{err}"]
    fits = [c for c in cells if c.get("status") == "ok"
            and c.get("est_fits_16gb") is False]
    rows.append(f"roofline,cells_overflow_est,{len(fits)}")
    for c in fits:
        rows.append(f"roofline,overflow,{c['arch']}/{c['shape']}/{c['mesh']}")
    return rows


if __name__ == "__main__":
    cells = load_cells()
    print(dryrun_table(cells))
    print()
    print(roofline_table(cells))
