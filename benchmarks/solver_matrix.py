"""Solver-stack matrix sweep: {solver x backend x precond x stencil}.

Three blocks, one JSON (``results/solver_matrix.json``):

* ``matrix`` — every registered solver and operator backend crossed with
  the preconditioners over the stencil family, each cell an end-to-end
  distributed solve (iterations, residual, wall time).  The problem tracks
  the solver: CG gets the symmetric Poisson operator, BiCGStab its
  nonsymmetric habitat.
* ``precond_headline`` — the acceptance experiment: unpreconditioned vs
  Jacobi vs Chebyshev BiCGStab on the Poisson star7 48x48x32 problem
  (paper-class mesh), reporting the iteration reduction; plus the raw
  variable-diagonal heterogeneous problem where Jacobi does real work.
* ``collectives`` — HLO AllReduce / collective-permute counts for one
  distributed iteration of the SPMD and Pallas-fused backends on a fake
  2x2 fabric (both must show the 3-AllReduce fused schedule).

Emits ``name,metric,value`` CSV rows (the benchmarks/run.py contract).
``--smoke`` shrinks every mesh for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time

MATRIX_SHAPES = ("star7", "star25", "box27")
_SUBPROC_DEVICES = 4

_COLLECTIVE_SNIPPET = """
    import json
    import jax, jax.numpy as jnp
    from repro.core import bicgstab, precision, stencil
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices({n})
    shape = {shape}
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
    structs = [jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), cf)]
    f32 = jax.ShapeDtypeStruct(shape, jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    structs += [f32, f32, f32, f32, scalar]
    out = {{}}
    for backend in ("spmd", "pallas"):
        it = bicgstab.make_iteration_fn(mesh, policy=precision.F32,
                                        backend=backend, fused_reductions=True)
        text = jax.jit(it).lower(*structs).as_text()
        out[backend] = {{
            "allreduce_per_iter": text.count("all_reduce") + text.count("all-reduce"),
            "ppermute_per_iter": (text.count("collective_permute")
                                  + text.count("collective-permute")),
        }}
    print(json.dumps(out))
"""


def measure_collectives(shape, n_devices: int = _SUBPROC_DEVICES) -> dict:
    """Per-iteration HLO collective counts for both distributed backends."""
    from benchmarks._subproc import run_hlo_subprocess

    return run_hlo_subprocess(
        _COLLECTIVE_SNIPPET.format(n=n_devices, shape=tuple(shape)),
        n_devices)


def solver_problem_kind(solver: str) -> str:
    """CG-family solvers need the symmetric operator ("bicgstab" contains
    "cg", so match exact names, not substrings)."""
    return "poisson" if solver in ("cg", "pipelined_cg") else "random"


def solver_tol(solver: str) -> float:
    """pipelined_cg's w-recurrence bounds attainable f32 accuracy (see
    core/solvers/pipelined.py); every other solver runs the tight default."""
    return 1e-5 if solver == "pipelined_cg" else 1e-6


def _solve_cell(mesh, cf, b, x_true, *, solver, backend, precond, tol,
                maxiter, policy):
    import jax
    import numpy as np
    from repro.core import bicgstab
    from repro.core.precond import PrecondConfig

    t0 = time.time()
    res = bicgstab.solve_distributed(
        mesh, cf, b, tol=tol, maxiter=maxiter, policy=policy,
        solver=solver, backend=backend,
        precond=PrecondConfig(name=precond))
    jax.block_until_ready(res.x)
    wall = time.time() - t0
    err = float(np.abs(np.asarray(res.x, np.float64)
                       - np.asarray(x_true, np.float64)).max())
    return {
        "iterations": int(res.iterations),
        "converged": bool(res.converged),
        "breakdown": bool(res.breakdown),
        "rel_residual": float(res.rel_residual),
        "max_err": err,
        "wall_s": wall,
    }


def sweep(*, smoke: bool = False, measure_hlo: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import precision, stencil
    from repro.core.solvers import SOLVERS
    from repro.launch.mesh import make_mesh_for_devices

    mesh = make_mesh_for_devices()
    matrix_shape = (12, 12, 8) if smoke else (16, 16, 8)
    headline_shape = (16, 16, 8) if smoke else (48, 48, 32)
    hetero_shape = (12, 12, 8) if smoke else (16, 16, 12)
    pol = precision.F32

    # --- the matrix ------------------------------------------------------
    cells = []
    shapes = ("star7",) if smoke else MATRIX_SHAPES
    for name in shapes:
        spec = stencil.get_spec(name)
        x_true = jax.random.normal(jax.random.PRNGKey(1), matrix_shape,
                                   jnp.float32)
        for solver in sorted(SOLVERS):
            problem = solver_problem_kind(solver)
            if problem == "poisson":
                cf = stencil.poisson(matrix_shape, spec=spec)
            else:
                cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0),
                                                 matrix_shape, spec=spec)
            b = stencil.rhs_for_solution(cf, x_true)
            for backend in ("spmd", "pallas"):
                for precond in ("none", "jacobi", "chebyshev"):
                    cell = _solve_cell(
                        mesh, cf, b, x_true, solver=solver, backend=backend,
                        precond=precond, tol=solver_tol(solver), maxiter=400,
                        policy=pol)
                    cells.append({
                        "stencil": name, "solver": solver,
                        "backend": backend, "precond": precond,
                        "problem": problem,
                        "problem_shape": list(matrix_shape),
                        **cell,
                    })

    # --- the acceptance headline ----------------------------------------
    cf = stencil.poisson(headline_shape)
    x_true = jax.random.normal(jax.random.PRNGKey(1), headline_shape,
                               jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    headline = {"problem": "poisson/star7",
                "problem_shape": list(headline_shape), "cells": {}}
    for precond in ("none", "jacobi", "chebyshev"):
        headline["cells"][precond] = _solve_cell(
            mesh, cf, b, x_true, solver="bicgstab", backend="spmd",
            precond=precond, tol=1e-6, maxiter=800, policy=pol)
    base = headline["cells"]["none"]["iterations"]
    for precond in ("jacobi", "chebyshev"):
        it = headline["cells"][precond]["iterations"]
        headline["cells"][precond]["iter_reduction_vs_none"] = (
            (base - it) / base if base else 0.0)

    cf = stencil.heterogeneous_poisson(jax.random.PRNGKey(3), hetero_shape)
    x_true = jax.random.normal(jax.random.PRNGKey(1), hetero_shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    hetero = {"problem": "heterogeneous (raw variable diagonal)",
              "problem_shape": list(hetero_shape), "cells": {}}
    for precond in ("none", "jacobi"):
        hetero["cells"][precond] = _solve_cell(
            mesh, cf, b, x_true, solver="bicgstab", backend="spmd",
            precond=precond, tol=1e-7, maxiter=3000, policy=pol)
    base = hetero["cells"]["none"]["iterations"]
    it = hetero["cells"]["jacobi"]["iterations"]
    hetero["cells"]["jacobi"]["iter_reduction_vs_none"] = (
        (base - it) / base if base else 0.0)

    record = {
        "generated_by": "benchmarks/solver_matrix.py",
        "schema": "repro.benchmark.v1",
        "smoke": smoke,
        "solve_fabric": "x".join(str(s) for s in mesh.devices.shape),
        "matrix": cells,
        "precond_headline": headline,
        "jacobi_headline": hetero,
    }
    if measure_hlo:
        record["collectives"] = measure_collectives((8, 8, 8))
        record["hlo_fabric_devices"] = _SUBPROC_DEVICES
    return record


def run(*, smoke: bool = False) -> list[str]:
    record = sweep(smoke=smoke)
    os.makedirs("results", exist_ok=True)
    path = os.path.join("results", "solver_matrix.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    from repro.obs.manifest import write_benchmark_bundle
    bundle_dir = write_benchmark_bundle("solver_matrix", record)
    rows = [f"solver_matrix,json_path,{path}"]
    rows.append(f"solver_matrix,run_bundle,{bundle_dir}")
    for c in record["matrix"]:
        tag = f"{c['stencil']}_{c['solver']}_{c['backend']}_{c['precond']}"
        assert c["converged"], f"matrix cell {tag} did not converge: {c}"
        rows.append(f"solver_matrix,{tag}_iters,{c['iterations']}")
    h = record["precond_headline"]["cells"]
    rows.append(f"solver_matrix,headline_none_iters,{h['none']['iterations']}")
    rows.append(f"solver_matrix,headline_cheb_iters,{h['chebyshev']['iterations']}")
    red = h["chebyshev"]["iter_reduction_vs_none"]
    rows.append(f"solver_matrix,headline_cheb_iter_reduction,{red:.3f}")
    assert red >= 0.30, (
        f"Chebyshev must cut BiCGStab iterations by >=30% on Poisson, got {red:.1%}")
    j = record["jacobi_headline"]["cells"]
    rows.append(f"solver_matrix,hetero_jacobi_iter_reduction,"
                f"{j['jacobi']['iter_reduction_vs_none']:.3f}")
    if "collectives" in record:
        for backend, counts in record["collectives"].items():
            n_ar = counts["allreduce_per_iter"]
            assert n_ar == 3, (
                f"{backend} backend must keep the 3-AllReduce fused "
                f"schedule, lowered to {n_ar}")
            rows.append(f"solver_matrix,{backend}_allreduce_per_iter,{n_ar}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny meshes (CI): same matrix, minutes not hours")
    args = ap.parse_args()
    for row in run(smoke=args.smoke):
        print(row)
