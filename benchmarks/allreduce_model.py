"""Paper §IV-3: the scalar AllReduce (1.5 us over ~380k cores, ~10% above
the fabric-diameter bound).

TPU counterpart: latency model for psum on the 16x16 (and 2x16x16) torus,
plus the measured AllReduce count per BiCGStab iteration from the compiled
HLO (3 fused vs 5 paper-faithful separate, 1 with the pipelined solvers) —
the schedule is the thing this repo controls; the per-hop latency is
hardware.  The per-iteration reduction-latency budget and the predicted
fabric size where the single-reduction pipelined schedule overtakes the
fused 3-AllReduce one come from ``perfmodel.SOLVER_COMMS`` /
``predict_crossover`` (measured counterpart: ``benchmarks/comm_overlap.py``).
"""

import json
import os

from repro.core.perfmodel import (
    SOLVER_COMMS, allreduce_latency, predict_crossover,
)


def run() -> list[str]:
    rows = []
    for name, (px, py, pz) in (("16x16", (16, 16, 1)), ("2x16x16", (16, 16, 2))):
        t = allreduce_latency(px, py, pz)
        rows.append(f"allreduce,model_{name}_us,{t * 1e6:.2f}")
        # per-iteration reduction latency budget per solver schedule
        for solver, comm in sorted(SOLVER_COMMS.items()):
            rows.append(f"allreduce,model_{name}_{solver}_iter_us,"
                        f"{comm.reductions_fused * t * 1e6:.2f}")
    rows.append("allreduce,cs1_measured_us,1.5")
    rows.append("allreduce,cs1_cores,380000")
    xo = predict_crossover((608, 608, 1536), {"solver": "bicgstab"},
                           {"solver": "pipelined_bicgstab"})
    rows.append(f"allreduce,pipelined_crossover_chips,{xo['crossover_chips']}")
    for tag in ("pod1", "pod2"):
        p = f"results/dryrun/cs1_paper__bicgstab_iter__{tag}.json"
        if os.path.exists(p):
            r = json.load(open(p))
            rows.append(f"allreduce,n_collectives_per_iter_{tag},"
                        f"{r['n_collectives']}")
    return rows
