"""Paper §IV-3: the scalar AllReduce (1.5 us over ~380k cores, ~10% above
the fabric-diameter bound).

TPU counterpart: latency model for psum on the 16x16 (and 2x16x16) torus,
plus the measured AllReduce count per BiCGStab iteration from the compiled
HLO (3 fused vs 5 paper-faithful separate) — the schedule is the thing this
repo controls; the per-hop latency is hardware.
"""

import json
import os

from repro.core.perfmodel import allreduce_latency


def run() -> list[str]:
    rows = []
    for name, (px, py, pz) in (("16x16", (16, 16, 1)), ("2x16x16", (16, 16, 2))):
        t = allreduce_latency(px, py, pz)
        rows.append(f"allreduce,model_{name}_us,{t * 1e6:.2f}")
    rows.append("allreduce,cs1_measured_us,1.5")
    rows.append("allreduce,cs1_cores,380000")
    for tag in ("pod1", "pod2"):
        p = f"results/dryrun/cs1_paper__bicgstab_iter__{tag}.json"
        if os.path.exists(p):
            r = json.load(open(p))
            rows.append(f"allreduce,n_collectives_per_iter_{tag},"
                        f"{r['n_collectives']}")
    return rows
