"""Shared fake-multi-device subprocess harness for HLO measurements.

Collective-count benchmarks need ``--xla_force_host_platform_device_count``
set *before* jax initializes, so each measurement runs a snippet in a fresh
subprocess and parses the JSON it prints on its last stdout line.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap


def run_hlo_subprocess(snippet: str, n_devices: int, *,
                       timeout: int = 900) -> dict:
    """Run ``snippet`` under an ``n_devices`` fake-device fabric; return the
    JSON object the snippet prints as its final line."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"collective-count subprocess failed:\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])
