"""Deterministic synthetic LM data pipeline.

Design constraints from the fault-tolerance story (DESIGN.md §9):

* **Stateless in (seed, step)** — every batch is a pure function of the run
  seed and the global step, so a restarted (or elastically resharded) job
  replays the exact token stream with zero pipeline state in checkpoints.
* **Host-sharded** — each host materializes only its slice of the global
  batch (`host_slice`); on a real pod this is per-host infeed, here it is
  exercised by tests with fake devices.
* **Prefetched** — a background thread keeps ``prefetch`` batches ready so
  step N+1's data is on device before step N finishes (straggler lever (a)).

The synthetic stream is a Zipf-ish unigram mixture with short repeated
motifs, which gives a *learnable* distribution (loss decreases measurably in
a few hundred steps — used by the convergence tests) rather than white noise.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64


class SyntheticLMData:
    def __init__(self, cfg: DataConfig, *, host_index: int = 0, n_hosts: int = 1,
                 extras: dict | None = None):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self.extras = extras or {}
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank: repeated n-grams the model can learn to predict
        self._motifs = rng.integers(
            0, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len), dtype=np.int32)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._unigram = p / p.sum()

    def batch_at(self, step: int) -> dict:
        """The global-step batch slice for this host. Pure in (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + self.host_index)
        B, T = self.local_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(B, T + 1), p=self._unigram).astype(np.int32)
        # splice motifs over ~half the positions => learnable structure
        n_splice = max(1, T // (2 * cfg.motif_len))
        for b in range(B):
            idx = rng.integers(0, cfg.n_motifs, size=n_splice)
            pos = rng.integers(0, T + 1 - cfg.motif_len, size=n_splice)
            for m, s in zip(idx, pos):
                toks[b, s : s + cfg.motif_len] = self._motifs[m]
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "loss_mask": np.ones((B, T), np.float32),
        }
        for name, (shape, dtype) in self.extras.items():
            # modality stubs (patch_embeds / frames): deterministic in step
            batch[name] = rng.standard_normal((B, *shape)).astype(dtype)
        return batch

    def iterate(self, start_step: int = 0, *, prefetch: int = 2):
        """Prefetching iterator: yields (step, batch) from start_step on."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch_at(step)))
                step += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass
