"""LM model zoo sharing the stencil framework's distribution substrate.

Pure-JAX (no flax): parameters are nested dicts of arrays; every parameter
is declared once as a :class:`repro.models.param.ParamDef` carrying its
logical sharding axes, from which both real initialization (smoke tests)
and abstract ``ShapeDtypeStruct`` trees with ``NamedSharding`` (dry-run)
are derived.
"""
