"""GQA attention block: projections + RoPE + flash/decode attention + cache.

Supports: grouped KV heads (kv=1..32), QKV bias (qwen2), sliding windows
(gemma3 5:1 local:global), prefix-LM masking (paligemma), cross-attention
(whisper decoder), logit softcap (grok), and sequence-sharded KV caches for
the decode/long shapes (the ``kv_seq`` logical axis).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef
from repro.models.layers import flash_attention, decode_attention, rope


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["k", "v", "length"], meta_fields=[],
)
@dataclasses.dataclass
class AttnCache:
    k: jax.Array          # (B, S, K, D)
    v: jax.Array          # (B, S, K, D)
    length: jax.Array     # int32 scalar: number of valid positions


def init_cache(batch: int, max_len: int, n_kv: int, d_head: int,
               dtype=jnp.bfloat16) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, max_len, n_kv, d_head), dtype),
        v=jnp.zeros((batch, max_len, n_kv, d_head), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def abstract_cache(batch: int, max_len: int, n_kv: int, d_head: int,
                   dtype=jnp.bfloat16) -> AttnCache:
    return AttnCache(
        k=jax.ShapeDtypeStruct((batch, max_len, n_kv, d_head), dtype),
        v=jax.ShapeDtypeStruct((batch, max_len, n_kv, d_head), dtype),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def build_params(d_model: int, n_heads: int, n_kv: int, d_head: int, *,
                 qkv_bias: bool = False, cross: bool = False,
                 dtype=jnp.bfloat16) -> dict:
    p = {
        "wq": ParamDef((d_model, n_heads, d_head), ("d_model", "heads", "head_dim"), dtype=dtype),
        "wk": ParamDef((d_model, n_kv, d_head), ("d_model", "kv_heads", "head_dim"), dtype=dtype),
        "wv": ParamDef((d_model, n_kv, d_head), ("d_model", "kv_heads", "head_dim"), dtype=dtype),
        "wo": ParamDef((n_heads, d_head, d_model), ("heads", "head_dim", "d_model"), dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = ParamDef((n_heads, d_head), ("heads", "head_dim"), init="zeros", dtype=dtype)
        p["bk"] = ParamDef((n_kv, d_head), ("kv_heads", "head_dim"), init="zeros", dtype=dtype)
        p["bv"] = ParamDef((n_kv, d_head), ("kv_heads", "head_dim"), init="zeros", dtype=dtype)
    if cross:
        p["c_wq"] = ParamDef((d_model, n_heads, d_head), ("d_model", "heads", "head_dim"), dtype=dtype)
        p["c_wk"] = ParamDef((d_model, n_kv, d_head), ("d_model", "kv_heads", "head_dim"), dtype=dtype)
        p["c_wv"] = ParamDef((d_model, n_kv, d_head), ("d_model", "kv_heads", "head_dim"), dtype=dtype)
        p["c_wo"] = ParamDef((n_heads, d_head, d_model), ("heads", "head_dim", "d_model"), dtype=dtype)
    return p


def _project(x, w, b=None):
    out = jnp.einsum("btd,dhe->bthe", x, w)
    return out + b[None, None] if b is not None else out


def self_attention(
    p: dict,
    x: jax.Array,                       # (B, T, d)
    *,
    n_kv: int,
    mode: str,                          # "train" | "prefill" | "decode"
    cache: AttnCache | None = None,
    positions: jax.Array | None = None, # (T,) absolute positions (train/prefill)
    causal: bool = True,
    window: int | None = None,
    prefix_len: int = 0,
    rope_theta: float | None = 1e4,
    softcap: float | None = None,
    block: int = 1024,
    unroll: bool = False,
):
    """Returns (out (B,T,d), new_cache)."""
    B, T, d = x.shape
    H, Dh = p["wq"].shape[1], p["wq"].shape[2]
    G = H // n_kv
    q = _project(x, p["wq"], p.get("bq"))
    k = _project(x, p["wk"], p.get("bk"))
    v = _project(x, p["wv"], p.get("bv"))

    if mode in ("train", "prefill"):
        if positions is None:
            positions = jnp.arange(T)
        pos_b = jnp.broadcast_to(positions[None], (B, T))
        if rope_theta is not None:
            q = rope(q, pos_b, rope_theta)
            k = rope(k, pos_b, rope_theta)
        qg = q.reshape(B, T, n_kv, G, Dh)
        if prefix_len > 0:
            # prefix-LM: bidirectional inside the prefix, causal after
            q_eff = jnp.maximum(positions, prefix_len - 1)
        else:
            q_eff = positions
        o = flash_attention(qg, k, v, q_eff, positions, causal=causal,
                            window=window, softcap=softcap, block=block,
                            unroll=unroll)
        new_cache = cache
        if mode == "prefill":
            assert cache is not None
            S = cache.k.shape[1]
            kpad = jnp.pad(k, ((0, 0), (0, S - T), (0, 0), (0, 0))) if S > T else k[:, :S]
            vpad = jnp.pad(v, ((0, 0), (0, S - T), (0, 0), (0, 0))) if S > T else v[:, :S]
            new_cache = AttnCache(kpad.astype(cache.k.dtype), vpad.astype(cache.v.dtype),
                                  jnp.int32(min(T, S)))
        out = jnp.einsum("bthe,hed->btd", o.reshape(B, T, H, Dh), p["wo"])
        return out, new_cache

    # decode: T == 1, append to cache then attend over the whole buffer
    assert cache is not None and T == 1
    pos = cache.length
    pos_b = jnp.broadcast_to(pos[None, None], (B, 1))
    if rope_theta is not None:
        q = rope(q, pos_b, rope_theta)
        k = rope(k, pos_b, rope_theta)
    kc = _ring_update(cache.k, k, pos)
    vc = _ring_update(cache.v, v, pos)
    new_len = jnp.minimum(pos + 1, cache.k.shape[1])
    o = decode_attention(q.reshape(B, 1, n_kv, G, Dh), kc, vc,
                         cache_len=pos + 1, k_pos0=0, window=window, softcap=softcap)
    out = jnp.einsum("bthe,hed->btd", o.reshape(B, 1, H, Dh), p["wo"])
    return out, AttnCache(kc, vc, new_len)


def _ring_update(buf: jax.Array, val: jax.Array, pos: jax.Array) -> jax.Array:
    """Write one timestep into the cache at ``pos`` (dynamic, clamped)."""
    pos = jnp.minimum(pos, buf.shape[1] - 1)
    return jax.lax.dynamic_update_slice(
        buf, val.astype(buf.dtype), (0, pos, 0, 0)
    )


def cross_attention(
    p: dict,
    x: jax.Array,                        # (B, T, d) decoder stream
    enc_kv: tuple[jax.Array, jax.Array],  # precomputed (k, v): (B, S_enc, K, Dh)
    *,
    n_kv: int,
    block: int = 1024,
    unroll: bool = False,
):
    """Whisper-style cross attention (no masking, no rope)."""
    B, T, d = x.shape
    H, Dh = p["c_wq"].shape[1], p["c_wq"].shape[2]
    G = H // n_kv
    q = _project(x, p["c_wq"]).reshape(B, T, n_kv, G, Dh)
    k, v = enc_kv
    S = k.shape[1]
    o = flash_attention(q, k, v, jnp.arange(T), jnp.arange(S), causal=False,
                        block=block, unroll=unroll)
    return jnp.einsum("bthe,hed->btd", o.reshape(B, T, H, Dh), p["c_wo"])


def encode_cross_kv(p: dict, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (cached once)."""
    return _project(enc_out, p["c_wk"]), _project(enc_out, p["c_wv"])
