"""Architecture assembly: configs, layer stacks, period-scan, caches.

Layers are scanned over *periods*: the repeating pattern of layer kinds
(dense archs: period=1; gemma3: 5 local + 1 global; jamba: 8-layer
attn/mamba interleave with alternating MoE).  Parameters and caches carry a
leading ``layers`` axis of length ``n_layers // len(period)`` so the HLO is
O(period) deep regardless of depth — essential for 512-device compile times
and the standard production pattern (MaxText-style scan + remat).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, mamba, moe, rwkv6
from repro.models.layers import layernorm, rmsnorm
from repro.models.param import ParamDef, constrain, stack_defs


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"              # attn | mamba | rwkv
    window: int | None = None       # sliding-window width (attn only)
    moe: bool = False               # MoE MLP instead of dense
    cross: bool = False             # + cross-attention (whisper decoder)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    period: tuple[LayerSpec, ...] = (LayerSpec(),)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_group_size: int = 2048
    moe_dispatch: str = "einsum"    # einsum (GSPMD-clean) | scatter (baseline)
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 1e4
    attn_softcap: float | None = None
    # SSM / RWKV
    rwkv_head_size: int = 64
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # structure
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str | None = None     # vlm | audio
    n_frontend_tokens: int = 256
    enc_len_decode: int = 1500      # whisper: frozen encoder frames at decode
    tie_embeddings: bool = True
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    scale_embed: bool = False       # gemma-style sqrt(d) embedding scale
    remat: bool = True
    dtype: Any = jnp.bfloat16
    # lowering/compile shape knobs (cost probes set these; see launch/dryrun.py)
    unroll: bool = False            # python-loop layers instead of lax.scan
    attn_block: int = 1024          # flash-attention KV block size
    rwkv_chunk: int = 64            # RWKV chunk-parallel width
    inner_unroll: bool = False      # fully unroll flash/RWKV inner scans
    # per-arch sharding-rule overrides ((key, axes) pairs), e.g. FSDP-style
    # weight sharding over the data axes for the 314B/52B archs
    rules: tuple = ()

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (self.name, self.n_layers, len(self.period))
        return self.n_layers // len(self.period)

    @property
    def sub_quadratic(self) -> bool:
        """True iff NO layer does full-context quadratic attention (long_500k gate)."""
        return all(s.kind != "attn" or s.window is not None for s in self.period)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs autoregress (whisper via its decoder)


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def _norm_defs(cfg: ArchConfig) -> dict:
    d = {"w": ParamDef((cfg.d_model,), ("d_model",), init="ones", dtype=jnp.float32)}
    if cfg.norm == "layernorm":
        d["b"] = ParamDef((cfg.d_model,), ("d_model",), init="zeros", dtype=jnp.float32)
    return d


def _apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(p["w"], p["b"], x)
    return rmsnorm(p["w"], x)


def build_layer_defs(cfg: ArchConfig, spec: LayerSpec) -> dict:
    d: dict = {"ln1": _norm_defs(cfg)}
    if spec.kind == "attn":
        d["attn"] = attention.build_params(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            qkv_bias=cfg.qkv_bias, cross=spec.cross, dtype=cfg.dtype)
        if spec.cross:
            d["ln_c"] = _norm_defs(cfg)
    elif spec.kind == "mamba":
        d["mamba"] = mamba.build_params(
            cfg.d_model, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv,
            expand=cfg.mamba_expand, dtype=cfg.dtype)
    elif spec.kind == "rwkv":
        d["rwkv"] = rwkv6.build_params(cfg.d_model, cfg.rwkv_head_size, cfg.d_ff,
                                       dtype=cfg.dtype)
        d["ln2"] = _norm_defs(cfg)
        return d
    else:
        raise ValueError(spec.kind)
    d["ln2"] = _norm_defs(cfg)
    if spec.moe:
        d["moe"] = moe.build_params(cfg.d_model, cfg.n_experts, cfg.d_ff_expert,
                                    n_shared=cfg.n_shared_experts, dtype=cfg.dtype)
    else:
        d["mlp"] = moe.build_dense_params(cfg.d_model, cfg.d_ff, act=cfg.act,
                                          dtype=cfg.dtype)
    return d


def build_model_defs(cfg: ArchConfig) -> dict:
    defs: dict = {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "d_model"),
                          init="embed", dtype=cfg.dtype),
        "final_norm": _norm_defs(cfg),
        "layers": {
            f"pos{i}": stack_defs(build_layer_defs(cfg, s), cfg.n_periods)
            for i, s in enumerate(cfg.period)
        },
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab), ("d_model", "vocab"),
                                   dtype=cfg.dtype)
    if cfg.enc_dec:
        enc_spec = LayerSpec(kind="attn", causal=False)
        defs["enc_layers"] = {
            "pos0": stack_defs(build_layer_defs(cfg, enc_spec), cfg.n_enc_layers)
        }
        defs["enc_norm"] = _norm_defs(cfg)
    return defs


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _layer_cache_defs(cfg: ArchConfig, spec: LayerSpec, batch: int, max_len: int,
                      enc_len: int) -> Any:
    if spec.kind == "attn":
        c = {
            "attn": attention.AttnCache(
                k=ParamDef((batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           ("batch", "kv_seq", "kv_heads", "head_dim"),
                           init="zeros", dtype=cfg.dtype),
                v=ParamDef((batch, max_len, cfg.n_kv_heads, cfg.d_head),
                           ("batch", "kv_seq", "kv_heads", "head_dim"),
                           init="zeros", dtype=cfg.dtype),
                length=ParamDef((), (), init="zeros", dtype=jnp.int32),
            )
        }
        if spec.cross:
            c["cross_k"] = ParamDef((batch, enc_len, cfg.n_kv_heads, cfg.d_head),
                                    ("batch", None, "kv_heads", "head_dim"),
                                    init="zeros", dtype=cfg.dtype)
            c["cross_v"] = ParamDef((batch, enc_len, cfg.n_kv_heads, cfg.d_head),
                                    ("batch", None, "kv_heads", "head_dim"),
                                    init="zeros", dtype=cfg.dtype)
        return c
    if spec.kind == "mamba":
        d_inner = cfg.mamba_expand * cfg.d_model
        return {
            "ssm": ParamDef((batch, d_inner, cfg.mamba_d_state),
                            ("batch", "heads_flat", "state"), init="zeros",
                            dtype=jnp.float32),
            "conv": ParamDef((batch, cfg.mamba_d_conv - 1, d_inner),
                             ("batch", None, "heads_flat"), init="zeros",
                             dtype=cfg.dtype),
        }
    if spec.kind == "rwkv":
        H = cfg.d_model // cfg.rwkv_head_size
        return {
            "wkv": ParamDef((batch, H, cfg.rwkv_head_size, cfg.rwkv_head_size),
                            ("batch", "heads", None, None), init="zeros",
                            dtype=jnp.float32),
            "tm_shift": ParamDef((batch, 1, cfg.d_model), ("batch", None, None),
                                 init="zeros", dtype=cfg.dtype),
            "cm_shift": ParamDef((batch, 1, cfg.d_model), ("batch", None, None),
                                 init="zeros", dtype=cfg.dtype),
        }
    raise ValueError(spec.kind)


def build_cache_defs(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    enc_len = cfg.enc_len_decode if cfg.enc_dec else 0
    return {
        f"pos{i}": stack_defs(_layer_cache_defs(cfg, s, batch, max_len, enc_len),
                              cfg.n_periods)
        for i, s in enumerate(cfg.period)
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def apply_layer(cfg: ArchConfig, spec: LayerSpec, p: dict, x: jax.Array, *,
                mode: str, cache: dict | None, positions, enc_out=None,
                prefix_len: int = 0):
    """One block. Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache = dict(cache) if cache is not None else None

    # layer-boundary sharding: sequence-parallel for full-sequence modes
    # (shrinks the remat stash 16x — the difference between fitting 16GB/chip
    # and not, see EXPERIMENTS §Dry-run); decode keeps seq unsharded (T=1).
    x = constrain(x, ("batch", "seq" if mode == "decode" else "seq_act",
                      "d_model"))
    if spec.kind == "attn":
        h = _apply_norm(cfg, p["ln1"], x)
        o, ac = attention.self_attention(
            p["attn"], h, n_kv=cfg.n_kv_heads, mode=mode,
            cache=cache["attn"] if cache else None, positions=positions,
            causal=spec.causal, window=spec.window, prefix_len=prefix_len,
            rope_theta=cfg.rope_theta, softcap=cfg.attn_softcap,
            block=cfg.attn_block, unroll=cfg.inner_unroll)
        x = x + o
        if new_cache is not None:
            new_cache["attn"] = ac
        if spec.cross:
            hc = _apply_norm(cfg, p["ln_c"], x)
            if mode == "decode":
                enc_kv = (cache["cross_k"], cache["cross_v"])
            else:
                enc_kv = attention.encode_cross_kv(p["attn"], enc_out)
                if new_cache is not None:
                    ek, ev = enc_kv
                    new_cache["cross_k"] = ek.astype(cfg.dtype)
                    new_cache["cross_v"] = ev.astype(cfg.dtype)
            x = x + attention.cross_attention(p["attn"], hc, enc_kv,
                                              n_kv=cfg.n_kv_heads,
                                              block=cfg.attn_block,
                                              unroll=cfg.inner_unroll)
    elif spec.kind == "mamba":
        h = _apply_norm(cfg, p["ln1"], x)
        if mode == "decode":
            o, (ssm, conv) = mamba.mamba_decode(p["mamba"], h, cache["ssm"], cache["conv"])
        else:
            o, (ssm, conv) = mamba.mamba_apply(p["mamba"], h)
        x = x + o
        if new_cache is not None:
            new_cache["ssm"], new_cache["conv"] = ssm, conv
    elif spec.kind == "rwkv":
        h = _apply_norm(cfg, p["ln1"], x)
        o, (wkv, tm_shift) = rwkv6.time_mix(
            p["rwkv"], h, head_size=cfg.rwkv_head_size,
            state=cache["wkv"] if cache else None,
            shift_prev=cache["tm_shift"] if cache else None,
            chunked=(mode != "decode"), chunk=cfg.rwkv_chunk,
            unroll=cfg.inner_unroll)
        x = x + o
        h = _apply_norm(cfg, p["ln2"], x)
        o, cm_shift = rwkv6.channel_mix(
            p["rwkv"], h, shift_prev=cache["cm_shift"] if cache else None)
        x = x + o
        if new_cache is not None:
            new_cache["wkv"], new_cache["tm_shift"] = wkv, tm_shift
            new_cache["cm_shift"] = cm_shift
        return x, new_cache, aux

    # MLP / MoE half (attn + mamba kinds)
    h = _apply_norm(cfg, p["ln2"], x)
    if spec.moe:
        o, aux = moe.moe_apply(p["moe"], h, n_experts=cfg.n_experts,
                               top_k=cfg.top_k, group_size=cfg.moe_group_size,
                               dispatch=cfg.moe_dispatch)
    else:
        o = moe.dense_apply(p["mlp"], h, act=cfg.act)
    x = x + o
    return x, new_cache, aux


def stack_apply(cfg: ArchConfig, layer_params: dict, x: jax.Array, *, mode: str,
                caches: dict | None, positions, enc_out=None, prefix_len: int = 0,
                period=None):
    """Scan the period pattern over n_periods. Returns (x, new_caches, aux)."""
    period = period or cfg.period

    def body(carry, xs):
        h, aux = carry
        pslice, cslice = xs
        new_cs = {}
        for i, spec in enumerate(period):
            key = f"pos{i}"
            h, nc, a = apply_layer(
                cfg, spec, pslice[key], h, mode=mode,
                cache=cslice[key] if cslice is not None else None,
                positions=positions, enc_out=enc_out, prefix_len=prefix_len)
            new_cs[key] = nc if nc is not None else {}
            aux = aux + a
        return (h, aux), new_cs

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body)

    if cfg.unroll:
        n = jax.tree.leaves(layer_params)[0].shape[0]
        carry = (x, jnp.float32(0.0))
        ys = []
        for i in range(n):
            xs_i = jax.tree.map(lambda a: a[i], (layer_params, caches))
            carry, y = body(carry, xs_i)
            ys.append(y)
        (x, aux) = carry
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *ys) if (
            caches is not None and ys and jax.tree.leaves(ys[0])) else None
        return x, (new_caches if caches is not None else None), aux

    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (layer_params, caches))
    return x, (new_caches if caches is not None else None), aux


def apply_head(cfg: ArchConfig, params: dict, x: jax.Array) -> jax.Array:
    """Final-norm'd hidden -> logits (tied or untied head), vocab-sharded."""
    head = params.get("lm_head", None)
    if head is None:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"])
    else:
        logits = jnp.einsum("btd,dv->btv", x, head)
    return constrain(logits, ("batch", "seq", "vocab"))


def forward(cfg: ArchConfig, params: dict, batch: dict, *, mode: str,
            caches: dict | None = None, return_logits: bool = True):
    """Unified forward. Returns (logits_or_hidden, new_caches, aux).

    batch keys: tokens (B,T) for train/prefill; token (B,1) for decode;
    + patch_embeds (B,P,d) for vlm; + frames (B,S_enc,d) for audio.
    ``return_logits=False`` returns the final-norm'd hidden states so the
    caller can chunk the (huge) vocab projection (train loss, prefill).
    """
    embed = params["embed"]

    def embed_tokens(t):
        x = embed[t]
        if cfg.scale_embed:
            x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
        return x

    enc_out = None
    prefix_len = 0
    if cfg.enc_dec and mode != "decode":
        enc = batch["frames"].astype(cfg.dtype)
        enc, _, _ = stack_apply(
            cfg, params["enc_layers"], enc, mode="train", caches=None,
            positions=jnp.arange(enc.shape[1]),
            period=(LayerSpec(kind="attn", causal=False),))
        enc_out = _apply_norm(cfg, params["enc_norm"], enc)

    if mode == "decode":
        x = embed_tokens(batch["token"])
        positions = None
    else:
        x = embed_tokens(batch["tokens"])
        if cfg.frontend == "vlm":
            pe = batch["patch_embeds"].astype(cfg.dtype)
            x = jnp.concatenate([pe, x], axis=1)
            prefix_len = pe.shape[1]
        positions = jnp.arange(x.shape[1])

    x, new_caches, aux = stack_apply(
        cfg, params["layers"], x, mode=mode, caches=caches, positions=positions,
        enc_out=enc_out, prefix_len=prefix_len)

    x = _apply_norm(cfg, params["final_norm"], x)
    if not return_logits:
        return x, new_caches, aux
    return apply_head(cfg, params, x), new_caches, aux
