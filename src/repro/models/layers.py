"""Shared NN layers: norms, RoPE, embeddings, GQA attention.

Attention is written flash-style in pure JAX (lax.scan over KV blocks with
online-softmax f32 accumulators) so that (a) the working set stays bounded
at 32k-500k contexts — the dry-run must *fit* — and (b) the same code path
lowers on CPU and TPU.  The Pallas kernels in repro.kernels are drop-in
replacements for the inner block on real TPU hardware.

Precision discipline follows the paper (§IV-3): 16-bit operands, f32
accumulation for every long reduction (softmax stats, attention PV sums,
norms, losses) — `preferred_element_type` everywhere a contraction feeds a
running sum.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def layernorm(w: jax.Array, b: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


def groupnorm_heads(w: jax.Array, b: jax.Array, x: jax.Array, eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm (RWKV's ln_x / GroupNorm over heads). x: (..., H, D)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, T, H, D) with D even; positions: (B, T) int32."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (B,T,D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style attention (train / prefill)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, *, causal: bool, window: int | None):
    """(Tq, Tk) boolean mask block from absolute positions."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def flash_attention(
    q: jax.Array,               # (B, Tq, K, G, D) grouped queries
    k: jax.Array,               # (B, Tk, K, D)
    v: jax.Array,               # (B, Tk, K, D)
    q_pos: jax.Array,           # (Tq,) absolute positions
    k_pos: jax.Array,           # (Tk,)
    *,
    causal: bool = True,
    window: int | None = None,
    block: int = 1024,
    softcap: float | None = None,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax attention, scanning KV blocks; f32 stats/accumulators.

    ``unroll=True`` fully unrolls the KV loop (cost probes: XLA counts loop
    bodies once, an unrolled graph is counted exactly)."""
    B, Tq, K, G, D = q.shape
    Tk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    block = min(block, Tk)
    n_blocks = math.ceil(Tk / block)
    pad = n_blocks * block - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
    kb = k.reshape(B, n_blocks, block, K, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, block, K, D).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(n_blocks, block)

    o0 = jnp.zeros((B, Tq, K, G, D), jnp.float32)
    m0 = jnp.full((B, Tq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Tq, K, G), jnp.float32)

    def step(carry, blk):
        o, m, l = carry
        kc, vc, pc = blk
        s = jnp.einsum("btkgd,bskd->btkgs", q, kc,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = _block_mask(q_pos, pc, causal=causal, window=window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("btkgs,bskd->btkgd", p.astype(v.dtype), vc,
                        preferred_element_type=jnp.float32)
        o = o * corr[..., None] + pv
        return (o, m_new, l), None

    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0), (kb, vb, pb),
                                unroll=n_blocks if unroll else 1)
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.astype(q.dtype)


def decode_attention(
    q: jax.Array,               # (B, 1, K, G, D)
    k_cache: jax.Array,         # (B, S, K, D)  (may be sequence-sharded)
    v_cache: jax.Array,
    cache_len: jax.Array,       # scalar or (B,) valid length
    k_pos0: int | jax.Array,    # absolute position of cache slot 0
    *,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) cache.

    Written as masked global softmax in f32; when the cache's S axis carries
    a mesh axis, XLA partitions the max/sum reductions into local partials
    plus two scalar-ish AllReduces — exactly the paper's low-latency
    AllReduce pattern (flash-decode for free via GSPMD).
    """
    B, S = k_cache.shape[0], k_cache.shape[1]
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bokgd,bskd->bkgs", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = k_pos0 + jnp.arange(S)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))     # (B or 1, S)
    if window is not None:
        q_pos = jnp.reshape(cache_len, (-1, 1)) - 1
        valid &= pos[None, :] > (q_pos - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bskd->bkgd", (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
                   v_cache, preferred_element_type=jnp.float32)
    return o[:, None].astype(q.dtype)   # (B, 1, K, G, D)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, targets: jax.Array, mask: jax.Array,
                       *, z_loss: float = 1e-4):
    """Next-token CE, vocab-shard-friendly. logits (B,T,V) stay 16-bit; the
    max / sum-exp / gold-gather reductions over V are partial-per-shard plus
    an AllReduce when V carries a mesh axis (the paper's reduction pattern);
    the one-hot einsum replaces take_along_axis so GSPMD partitions cleanly.
    """
    m = jnp.max(logits, axis=-1, keepdims=True)                  # max: exact in bf16
    se = jnp.sum(jnp.exp((logits - m).astype(jnp.float32)), axis=-1)
    lse = m[..., 0].astype(jnp.float32) + jnp.log(se)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("btv,btv->bt", logits, onehot,
                      preferred_element_type=jnp.float32)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
