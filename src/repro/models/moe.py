"""Mixture-of-Experts with group-aligned capacity dispatch.

Design for the production mesh (see DESIGN.md §6): tokens stay sharded over
the batch axes; dispatch happens *within* a token group that is aligned with
the data sharding, so routing involves no cross-device traffic at all.
Expert FFN weights are sharded tensor-parallel on the hidden (ff) dimension
— the one dimension that divides the 16-way model axis for every assigned
MoE arch (qwen2-moe E=60, grok E=8, jamba E=16) — so the only collective per
MoE layer is the same single AllReduce a dense TP MLP needs.  When E divides
the model axis (jamba) the `experts` logical axis additionally shards the
expert weights (expert parallelism), which GSPMD turns into all-gather-free
grouped matmuls.

Dispatch is scatter-based (no (S, E, C) one-hot): positions inside each
expert's capacity buffer come from a per-group cumulative sum, dropped
tokens simply keep their residual value (dropless-for-small-batches via the
capacity clamp in `capacity()`).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef, constrain
from repro.models.layers import swiglu


def capacity(tokens_per_group: int, n_experts: int, top_k: int,
             factor: float = 1.25) -> int:
    """Per-group per-expert buffer size; clamped so tiny decode groups never drop."""
    c = math.ceil(tokens_per_group * top_k / n_experts * factor)
    return max(min(tokens_per_group, max(c, top_k)), 1)


def build_params(d_model: int, n_experts: int, d_ff: int, *, n_shared: int = 0,
                 dtype=jnp.bfloat16) -> dict:
    p = {
        "router": ParamDef((d_model, n_experts), ("d_model", None), dtype=jnp.float32),
        "w_gate": ParamDef((n_experts, d_model, d_ff), ("experts", "d_model", "expert_ff"), dtype=dtype),
        "w_up": ParamDef((n_experts, d_model, d_ff), ("experts", "d_model", "expert_ff"), dtype=dtype),
        "w_down": ParamDef((n_experts, d_ff, d_model), ("experts", "expert_ff", "d_model"), dtype=dtype),
    }
    if n_shared:
        ff_sh = n_shared * d_ff
        p["shared_gate"] = ParamDef((d_model, ff_sh), ("d_model", "ff"), dtype=dtype)
        p["shared_up"] = ParamDef((d_model, ff_sh), ("d_model", "ff"), dtype=dtype)
        p["shared_down"] = ParamDef((ff_sh, d_model), ("ff", "d_model"), dtype=dtype)
        p["shared_coef"] = ParamDef((d_model, 1), ("d_model", None), dtype=jnp.float32)
    return p


def moe_apply(params: dict, x: jax.Array, *, n_experts: int, top_k: int,
              group_size: int = 2048, cap_factor: float = 1.25,
              router_weights_renorm: bool = True, dispatch: str = "einsum"):
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar).

    dispatch:
      * "einsum"  — GShard-style one-hot dispatch/combine einsums.  Pure
        matmuls => GSPMD partitions them perfectly (groups over batch axes,
        expert ff over model).  Costs extra dispatch flops ~ g*E*cap*d per
        group but ZERO dispatch collectives.  Default after the hillclimb of
        EXPERIMENTS.md §Perf (the scatter path all-gathers tens of GB/layer).
      * "scatter" — positional scatter/gather dispatch (fewer flops, but the
        batched scatter defeats the SPMD partitioner at 512 devices; kept as
        the measured baseline and for single-device use).
    """
    B, T, d = x.shape
    n_tok = B * T
    g = min(group_size, n_tok)
    while n_tok % g:
        g //= 2
    n_groups = n_tok // g
    E, k = n_experts, top_k
    cap = capacity(g, E, k, cap_factor)

    xt = x.reshape(n_groups, g, d)
    # group placement is a sharding-policy decision: by default groups follow
    # the batch axes; the expert-data-parallel variant (§Perf) also spreads
    # them over the model axis with replicated expert weights.
    xt = constrain(xt, ("moe_groups", None, "d_model"))
    logits = jnp.einsum("nsd,de->nse", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, e_idx = jax.lax.top_k(probs, k)                          # (n, g, k)
    if router_weights_renorm:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[e_idx.reshape(-1)].add(1.0) / (n_tok * k)
    aux = E * jnp.sum(me * ce)

    if dispatch == "einsum":
        out = _einsum_dispatch(params, xt, e_idx, w, cap, E)
        out = constrain(out, ("moe_groups", None, "d_model"))
        out = out.reshape(B, T, d)
        return _add_shared(params, x, out), aux

    def per_group(xg, eg, wg):
        # position of each (token, choice) inside its expert's buffer
        flat_e = eg.reshape(-1)                                 # (g*k,)
        oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (g*k, E)
        pos = jnp.cumsum(oh, axis=0) - oh                       # exclusive per-expert count
        pos = (pos * oh).sum(-1)                                # (g*k,)
        keep = pos < cap
        tok = jnp.repeat(jnp.arange(g), k)
        buf = jnp.zeros((E, cap, d), xg.dtype)
        buf = buf.at[
            jnp.where(keep, flat_e, 0), jnp.where(keep, pos, cap - 1)
        ].add(jnp.where(keep[:, None], xg[tok], 0).astype(xg.dtype), mode="drop")
        return buf, (flat_e, pos, keep, tok)

    buf, (flat_e, pos, keep, tok) = jax.vmap(per_group)(xt, e_idx, w)
    # expert FFN (grouped SwiGLU); ff dim is TP-sharded => one AllReduce at down-proj
    gate = jnp.einsum("necd,edf->necf", buf, params["w_gate"])
    up = jnp.einsum("necd,edf->necf", buf, params["w_up"])
    act = swiglu(gate, up)
    out_buf = jnp.einsum("necf,efd->necd", act, params["w_down"])

    def per_group_combine(ob, fe, ps, kp, tk, wg):
        vals = ob[fe, ps]                                       # (g*k, d)
        wflat = wg.reshape(-1)
        vals = vals * (wflat * kp)[:, None].astype(ob.dtype)
        return jnp.zeros((g, d), ob.dtype).at[tk].add(vals)

    out = jax.vmap(per_group_combine)(out_buf, flat_e, pos, keep, tok, w)
    out = out.reshape(B, T, d)
    return _add_shared(params, x, out), aux


def _einsum_dispatch(params, xt, e_idx, w, cap: int, E: int):
    """GShard dispatch: per-choice-rank one-hot (g, E, cap) masks + einsums.

    Position-in-expert is an exclusive cumsum over the group per rank (plus
    counts from earlier ranks), the standard capacity assignment; tokens
    beyond capacity drop (they keep their residual value).  Everything is
    elementwise/cumsum/einsum => GSPMD partitions along the group axis with
    zero dispatch collectives.
    """
    n, g, d = xt.shape
    k = e_idx.shape[-1]
    disp = jnp.zeros((n, g, E, cap), xt.dtype)
    comb = jnp.zeros((n, g, E, cap), xt.dtype)
    counts = jnp.zeros((n, 1, E), jnp.int32)
    for j in range(k):
        oh_j = jax.nn.one_hot(e_idx[..., j], E, dtype=jnp.int32)    # (n,g,E)
        pos_j = jnp.cumsum(oh_j, axis=1) - oh_j + counts
        keep = ((pos_j < cap) & (oh_j > 0)).astype(xt.dtype)
        d_j = jax.nn.one_hot(pos_j, cap, dtype=xt.dtype) * keep[..., None]
        disp = disp + d_j
        comb = comb + d_j * w[:, :, j, None, None].astype(xt.dtype)
        counts = counts + oh_j.sum(axis=1, keepdims=True)
    buf = jnp.einsum("ngd,ngec->necd", xt, disp)
    gate = jnp.einsum("necd,edf->necf", buf, params["w_gate"])
    up = jnp.einsum("necd,edf->necf", buf, params["w_up"])
    act = swiglu(gate, up)
    out_buf = jnp.einsum("necf,efd->necd", act, params["w_down"])
    return jnp.einsum("ngec,necd->ngd", comb, out_buf)


def _add_shared(params, x, out):
    if "shared_gate" in params:
        sh = swiglu(x @ params["shared_gate"], x @ params["shared_up"]) @ params["shared_down"]
        coef = jax.nn.sigmoid(
            jnp.einsum("btd,do->bto", x.astype(jnp.float32), params["shared_coef"]))
        out = out + sh * coef.astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def build_dense_params(d_model: int, d_ff: int, *, act: str = "swiglu",
                       dtype=jnp.bfloat16) -> dict:
    if act in ("swiglu", "geglu"):
        return {
            "gate": ParamDef((d_model, d_ff), ("d_model", "ff"), dtype=dtype),
            "up": ParamDef((d_model, d_ff), ("d_model", "ff"), dtype=dtype),
            "down": ParamDef((d_ff, d_model), ("ff", "d_model"), dtype=dtype),
        }
    return {  # plain gelu (whisper)
        "up": ParamDef((d_model, d_ff), ("d_model", "ff"), dtype=dtype),
        "up_b": ParamDef((d_ff,), ("ff",), init="zeros", dtype=dtype),
        "down": ParamDef((d_ff, d_model), ("ff", "d_model"), dtype=dtype),
        "down_b": ParamDef((d_model,), ("d_model",), init="zeros", dtype=dtype),
    }


def dense_apply(params: dict, x: jax.Array, *, act: str = "swiglu") -> jax.Array:
    from repro.models.layers import gelu
    if "gate" in params:
        g = (x @ params["gate"]).astype(jnp.float32)
        up = x @ params["up"]
        gated = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g, approximate=True)
        return (gated.astype(up.dtype) * up) @ params["down"]
    return gelu(x @ params["up"] + params["up_b"]) @ params["down"] + params["down_b"]
