"""Mamba (S6 selective SSM) block for Jamba's hybrid layers (arXiv:2312.00752,
Jamba arXiv:2403.19887).

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t        (diag A, per channel)
    y_t = C_t . h_t + D x_t

Train/prefill evaluates the diagonal linear recurrence with an associative
scan over time (parallel, TPU-friendly); decode keeps O(1) state.  The
``d_inner`` channel dimension carries the ``heads_flat`` logical axis so all
per-channel work is tensor-parallel on the model axis; only the out-proj
contraction AllReduces — same collective budget as a dense TP MLP.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef


def build_params(d_model: int, *, d_state: int = 16, d_conv: int = 4,
                 expand: int = 2, dt_rank: int | None = None,
                 dtype=jnp.bfloat16) -> dict:
    d_inner = expand * d_model
    dt_rank = dt_rank or math.ceil(d_model / 16)
    return {
        "in_proj": ParamDef((d_model, 2 * d_inner), ("d_model", "heads_flat"), dtype=dtype),
        "conv_w": ParamDef((d_conv, d_inner), ("conv", "heads_flat"), dtype=dtype),
        "conv_b": ParamDef((d_inner,), ("heads_flat",), init="zeros", dtype=dtype),
        "x_proj": ParamDef((d_inner, dt_rank + 2 * d_state), ("heads_flat", None), dtype=dtype),
        "dt_w": ParamDef((dt_rank, d_inner), (None, "heads_flat"), dtype=dtype),
        "dt_b": ParamDef((d_inner,), ("heads_flat",), init="ones", dtype=jnp.float32),
        "A_log": ParamDef((d_inner, d_state), ("heads_flat", "state"), init="ones", dtype=jnp.float32),
        "D": ParamDef((d_inner,), ("heads_flat",), init="ones", dtype=jnp.float32),
        "out_proj": ParamDef((d_inner, d_model), ("heads_flat", "d_model"), dtype=dtype),
        "norm_w": ParamDef((d_inner,), ("heads_flat",), init="ones", dtype=jnp.float32),
    }


def _ssm_inputs(p, x):
    """Shared front half: projections, conv, dt/B/C/A discretization."""
    B_, T, _ = x.shape
    d_inner = p["conv_b"].shape[0]
    d_state = p["A_log"].shape[1]
    dt_rank = p["dt_w"].shape[0]
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    return xs, z, d_inner, d_state, dt_rank


def _discretize(p, xs):
    d_state = p["A_log"].shape[1]
    dt_rank = p["dt_w"].shape[0]
    proj = xs @ p["x_proj"]                                     # (B,T,R+2N)
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_in.astype(jnp.float32),
                   p["dt_w"].astype(jnp.float32)) + p["dt_b"])       # (B,T,d_in) f32
    A = -jnp.exp(p["A_log"])                                    # (d_in, N) f32
    da = jnp.exp(dt[..., None] * A[None, None])                 # (B,T,d_in,N)
    db = dt[..., None] * Bc[:, :, None, :].astype(jnp.float32)  # (B,T,d_in,N)
    return da, db, Cc, dt


def _causal_conv(p, xs, conv_state=None):
    """Depthwise causal conv1d (k=d_conv). conv_state: (B, k-1, d_inner)."""
    k = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xs.shape[0], k - 1, xs.shape[-1]), xs.dtype)
    xpad = jnp.concatenate([conv_state, xs], axis=1)
    out = sum(
        xpad[:, i : i + xs.shape[1]] * p["conv_w"][i][None, None]
        for i in range(k)
    ) + p["conv_b"]
    new_state = xpad[:, -(k - 1):]
    return jax.nn.silu(out.astype(jnp.float32)).astype(xs.dtype), new_state


def mamba_apply(p, x, *, state=None, conv_state=None):
    """Full sequence (train/prefill) via associative scan.

    Returns (out, (ssm_state (B,d_in,N) f32, conv_state (B,k-1,d_in))).
    """
    B_, T, _ = x.shape
    xs, z, d_inner, d_state, _ = _ssm_inputs(p, x)
    xs, conv_state = _causal_conv(p, xs, conv_state)
    da, db, Cc, dt = _discretize(p, xs)
    bx = db * xs.astype(jnp.float32)[..., None]                 # (B,T,d_in,N)
    if state is not None:
        # fold the carried state into the first step: h_0' contribution
        bx = bx.at[:, 0].add(da[:, 0] * state)

    def combine(a, b):
        # linear recurrence h' = a2*(a1*h + b1) + b2 composition
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (da, bx), axis=1)
    y = jnp.einsum("btdn,btn->btd", h, Cc.astype(jnp.float32))
    y = y + p["D"][None, None] * xs.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # Jamba applies an RMSNorm before out-proj
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6) * p["norm_w"]).astype(x.dtype)
    out = y @ p["out_proj"]
    new_state = h[:, -1]
    return out, (new_state, conv_state)


def mamba_decode(p, x, state, conv_state):
    """One-token step, O(1) state. x: (B, 1, d)."""
    xs, z, d_inner, d_state, _ = _ssm_inputs(p, x)
    xs, conv_state = _causal_conv(p, xs, conv_state)
    da, db, Cc, dt = _discretize(p, xs)
    h = da[:, 0] * state + db[:, 0] * xs.astype(jnp.float32)[:, 0, :, None]
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0].astype(jnp.float32))
    y = y + p["D"][None] * xs.astype(jnp.float32)[:, 0]
    y = y * jax.nn.silu(z.astype(jnp.float32))[:, 0]
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6) * p["norm_w"]).astype(x.dtype)
    out = (y @ p["out_proj"])[:, None]
    return out, (h, conv_state)
