"""Parameter declaration + logical-axis sharding (MaxText-style rules).

Each parameter is declared once with *logical* axes; `mesh_rules` maps the
logical names onto physical mesh axes, dropping any mapping that does not
divide evenly (replicate instead).  That single degradation rule absorbs all
the per-arch irregularities (whisper's 20 heads on a 16-way model axis,
qwen2-moe's 60 experts, batch-1 long-context decode, ...), which is what
lets one sharding policy serve 10 architectures x 4 shapes.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]            # logical axis names, len == len(shape)
    init: str = "normal"                    # normal | zeros | ones | embed
    scale: float | None = None              # override fan-in scaling
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# Logical axis -> preferred mesh axis (or tuple). None = always replicated.
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "heads_flat": "model",    # flattened (H*Dh) projections (RWKV, Mamba d_inner)
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_ff": "model",
    "moe_groups": ("pod", "data"),   # MoE token groups follow the batch axes
    "d_model": None,
    "seq": None,
    "seq_act": "model",       # Megatron-SP: layer-boundary activations and the
                              # remat stash shard the sequence over the model
                              # axis; GSPMD inserts the AG/RS around attn/mlp
    "kv_seq": "model",        # decode shapes: flash-decode sequence sharding
    "conv": None,
    "state": None,
    "layers": None,           # stacked-period leading axis
}


# Cell-scoped sharding-rule overrides (e.g. long_500k decode: batch=1 leaves
# the data axes idle, so weights/KV re-shard over ("model","data")).  Set via
# `with rule_overrides({...}):` around both spec construction AND tracing so
# `constrain` sees the same rules.
_RULE_OVERRIDES: contextvars.ContextVar[dict] = contextvars.ContextVar(
    "repro_rule_overrides", default={})


@contextlib.contextmanager
def rule_overrides(rules: dict):
    tok = _RULE_OVERRIDES.set({**_RULE_OVERRIDES.get(), **rules})
    try:
        yield
    finally:
        _RULE_OVERRIDES.reset(tok)


def _mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)  # works for Mesh and AbstractMesh


def constrain(x: jax.Array, axes: tuple[str | None, ...], rules: dict | None = None):
    """Constrain an activation's sharding by logical axes, if a mesh is ambient.

    Outside ``jax.sharding.set_mesh`` (smoke tests, single device) this is a
    no-op, so model code stays mesh-agnostic.
    """
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    return jax.lax.with_sharding_constraint(
        x, physical_spec(x.shape, axes, mesh, rules))


def physical_spec(shape: tuple[int, ...], axes: tuple[str | None, ...], mesh,
                  rules: dict | None = None) -> P:
    """Logical axes -> PartitionSpec, dropping non-dividing mappings."""
    rules = {**DEFAULT_RULES, **_RULE_OVERRIDES.get(), **(rules or {})}
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    out = []
    for dim, ax in zip(shape, axes):
        phys = rules.get(ax) if ax is not None else None
        if phys is None:
            out.append(None)
            continue
        cand = (phys,) if isinstance(phys, str) else tuple(phys)
        cand = tuple(a for a in cand if a in sizes and a not in used)
        total = math.prod(sizes[a] for a in cand) if cand else 1
        if cand and dim % total == 0:
            out.append(cand if len(cand) > 1 else cand[0])
            used.update(cand)
        else:
            # try shrinking a multi-axis mapping from the left (e.g. batch on
            # ("pod","data") where only "data" divides)
            placed = None
            for i in range(1, len(cand)):
                sub = cand[i:]
                t = math.prod(sizes[a] for a in sub)
                if dim % t == 0:
                    placed = sub if len(sub) > 1 else sub[0]
                    used.update(sub)
                    break
            out.append(placed)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_tree(defs, mesh, rules: dict | None = None):
    """ParamDef tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda d: NamedSharding(mesh, physical_spec(d.shape, d.axes, mesh, rules)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def zero1_spec(shape: tuple[int, ...], axes: tuple[str | None, ...], mesh,
               rules: dict | None = None) -> P:
    """ZeRO-1: the parameter's spec plus the batch axes spread over the
    largest still-unsharded dividing dimension.  Used for optimizer moments
    (and implicitly gradients, which GSPMD then reduce-scatters): f32 Adam
    state is 4x the bf16 params — without this it dominates the footprint
    (EXPERIMENTS §Dry-run)."""
    base = physical_spec(shape, axes, mesh, rules)
    entries = list(base) + [None] * (len(shape) - len(base))
    sizes = _mesh_axis_sizes(mesh)
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    free = [a for a in ("data", "pod") if a in sizes and a not in used]
    if free:
        extra = math.prod(sizes[a] for a in free)
        for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
            if entries[i] is None and shape[i] % extra == 0:
                entries[i] = tuple(free) if len(free) > 1 else free[0]
                break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def zero1_sharding_tree(defs, mesh, rules: dict | None = None):
    return jax.tree.map(
        lambda d: NamedSharding(mesh, zero1_spec(d.shape, d.axes, mesh, rules)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )


def abstract_tree(defs, mesh=None, rules: dict | None = None):
    """ParamDef tree -> ShapeDtypeStruct tree (with shardings when mesh given)."""
    def mk(d: ParamDef):
        sh = None
        if mesh is not None:
            sh = NamedSharding(mesh, physical_spec(d.shape, d.axes, mesh, rules))
        return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=sh)
    return jax.tree.map(mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def init_tree(defs, key: jax.Array):
    """ParamDef tree -> real parameter arrays (smoke/test scale only)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))

    def mk(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "embed":
            # unit-variance rows scaled by 1/sqrt(d) so tied logits start O(1)
            s = 1.0 / math.sqrt(d.shape[-1])
            return (s * jax.random.normal(k, d.shape, jnp.float32)).astype(d.dtype)
        fan_in = d.shape[0] if len(d.shape) == 1 else math.prod(d.shape[:-1])
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(k, d.shape, jnp.float32)).astype(d.dtype)

    return treedef.unflatten([mk(d, k) for d, k in zip(leaves, keys)])


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(math.prod(d.shape) for d in leaves)


def stack_defs(defs, n: int):
    """Prepend a ``layers`` axis of length n to every ParamDef in the tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef),
    )
