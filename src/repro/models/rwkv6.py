"""RWKV-6 "Finch" time-mix / channel-mix (arXiv:2404.05892).

The WKV6 recurrence per head (state S in R^{dk x dv}):

    o_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ,   w_t = exp(-exp(decay_t))

with data-dependent decay (the Finch novelty) and data-dependent token-shift
interpolation (ddlerp).  Two equivalent evaluation orders are provided:

* ``wkv_recurrent`` — O(1)-state scan over time: decode path and the oracle.
* ``wkv_chunked``  — chunk-parallel form (within-chunk "attention" matrix +
  cross-chunk state carry): the train/prefill path.  This is the stencil
  paper's discipline applied to a linear recurrence: per-chip chunks with a
  carried state playing the role of the halo.

Relation to the paper: the sequence dimension here is the Z-pencil of
Fig. 3 — the state carry between chunks is a one-sided halo exchange, and
``long_500k`` shards chunks across the fabric with ppermute state passing.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.param import ParamDef
from repro.models.layers import groupnorm_heads


LORA_R = 32
DDLERP_R = 32


def build_params(d_model: int, head_size: int, d_ff: int, *, dtype=jnp.bfloat16) -> dict:
    H = d_model // head_size
    return {
        # time-mix (token-shift) static lerps + data-dependent lora (5 mixes: r,k,v,w,g)
        "mu": ParamDef((5, d_model), (None, "d_model"), init="zeros", dtype=jnp.float32),
        "ddlerp_w1": ParamDef((d_model, 5, DDLERP_R), ("d_model", None, None), dtype=dtype),
        "ddlerp_w2": ParamDef((5, DDLERP_R, d_model), (None, None, "d_model"), dtype=dtype),
        # projections
        "w_r": ParamDef((d_model, d_model), ("d_model", "heads_flat"), dtype=dtype),
        "w_k": ParamDef((d_model, d_model), ("d_model", "heads_flat"), dtype=dtype),
        "w_v": ParamDef((d_model, d_model), ("d_model", "heads_flat"), dtype=dtype),
        "w_g": ParamDef((d_model, d_model), ("d_model", "heads_flat"), dtype=dtype),
        "w_o": ParamDef((d_model, d_model), ("heads_flat", "d_model"), dtype=dtype),
        # decay: w0 + tanh(x @ A) @ B   (data-dependent, per channel)
        "decay_base": ParamDef((d_model,), ("heads_flat",), init="zeros", dtype=jnp.float32),
        "decay_A": ParamDef((d_model, LORA_R), ("d_model", None), dtype=dtype),
        "decay_B": ParamDef((LORA_R, d_model), (None, "heads_flat"), dtype=dtype),
        # per-channel bonus u and output groupnorm
        "bonus": ParamDef((H, head_size), ("heads", "head_dim"), init="zeros", dtype=jnp.float32),
        "ln_x_w": ParamDef((H, head_size), ("heads", "head_dim"), init="ones", dtype=jnp.float32),
        "ln_x_b": ParamDef((H, head_size), ("heads", "head_dim"), init="zeros", dtype=jnp.float32),
        # channel-mix
        "cm_mu": ParamDef((2, d_model), (None, "d_model"), init="zeros", dtype=jnp.float32),
        "cm_k": ParamDef((d_model, d_ff), ("d_model", "ff"), dtype=dtype),
        "cm_v": ParamDef((d_ff, d_model), ("ff", "d_model"), dtype=dtype),
        "cm_r": ParamDef((d_model, d_model), ("d_model", "d_model"), dtype=dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """x_{t-1} stream; prev = last token of the previous segment (or zeros)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p, x, x_prev):
    """Data-dependent interpolation producing the 5 mixed inputs (r,k,v,w,g)."""
    dx = (x_prev - x).astype(jnp.float32)
    # f32 operands: XLA CPU has no bf16xbf16->f32 thunk for these contractions
    inner = jnp.tanh(jnp.einsum("btd,dmr->btmr", dx,
                                p["ddlerp_w1"].astype(jnp.float32)))
    lora = jnp.einsum("btmr,mrd->btmd", inner, p["ddlerp_w2"].astype(jnp.float32))
    mix = p["mu"][None, None] + lora                            # (B,T,5,d) f32
    return (x[:, :, None].astype(jnp.float32) + dx[:, :, None] * mix).astype(x.dtype)


def _project(p, x, x_prev, head_size: int):
    B, T, d = x.shape
    H = d // head_size
    mixed = _ddlerp(p, x, x_prev)                               # (B,T,5,d)
    xr, xk, xv, xw, xg = [mixed[:, :, i] for i in range(5)]
    r = (xr @ p["w_r"]).reshape(B, T, H, head_size)
    k = (xk @ p["w_k"]).reshape(B, T, H, head_size)
    v = (xv @ p["w_v"]).reshape(B, T, H, head_size)
    g = jax.nn.silu((xg @ p["w_g"]).astype(jnp.float32)).astype(x.dtype)
    decay_in = jnp.tanh(jnp.einsum("btd,dr->btr", xw.astype(jnp.float32),
                                   p["decay_A"].astype(jnp.float32)))
    logw = p["decay_base"][None, None] + jnp.einsum(
        "btr,rd->btd", decay_in, p["decay_B"].astype(jnp.float32))
    # w in (0,1): w = exp(-exp(logw)); keep log-decay = -exp(logw) (f32)
    log_decay = -jnp.exp(jnp.clip(logw, -10.0, 6.0)).reshape(B, T, H, head_size)
    return r, k, v, g, log_decay


def wkv_recurrent(r, k, v, log_decay, bonus, state):
    """Scan over time. r/k/v: (B,T,H,D); state: (B,H,D,D) f32. Returns (o, state)."""
    B, T, H, D = r.shape

    def step(S, inp):
        rt, kt, vt, ld = inp                                    # (B,H,D)
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                         S + bonus[None, :, :, None] * kv)
        S = jnp.exp(ld)[..., None] * S + kv
        return S, out

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_decay.transpose(1, 0, 2, 3))
    state, out = jax.lax.scan(step, state, xs)
    return out.transpose(1, 0, 2, 3).astype(r.dtype), state


def wkv_chunked(r, k, v, log_decay, bonus, state, *, chunk: int = 64,
                unroll: bool = False):
    """Chunk-parallel WKV6. Equivalent to the recurrence (tested)."""
    B, T, H, D = r.shape
    C = min(chunk, T)
    while T % C:
        C //= 2
    n = T // C
    rs = lambda a: a.reshape(B, n, C, H, D).transpose(1, 0, 2, 3, 4)  # (n,B,C,H,D)
    rc, kc, vc, ldc = rs(r.astype(jnp.float32)), rs(k.astype(jnp.float32)), \
        rs(v.astype(jnp.float32)), rs(log_decay.astype(jnp.float32))

    def chunk_step(S, inp):
        rb, kb, vb, ld = inp                                    # (B,C,H,D)
        P = jnp.cumsum(ld, axis=1)                              # inclusive log-decay prods
        Pm1 = P - ld                                            # exclusive (P_{t-1})
        # cross-chunk: o_cross[t] = (r_t * exp(Pm1_t)) . S_in
        r_dec = rb * jnp.exp(Pm1)
        o = jnp.einsum("bthk,bhkv->bthv", r_dec, S)
        # within-chunk: A[t,s] = sum_k r_t[k] k_s[k] exp(Pm1_t - P_s)[k], s < t
        att = jnp.einsum("bthk,bshk->bhts", r_dec, kb * jnp.exp(-P))
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        o = o + jnp.einsum("bhts,bshv->bthv", att, vb)
        # bonus diagonal term (s == t)
        o = o + (rb * bonus[None, None] * kb).sum(-1, keepdims=True) * vb
        # state update: S_out = diag(exp(P_C)) S + sum_s diag(exp(P_C - P_s)) k_s v_s
        PC = P[:, -1:]                                          # (B,1,H,D)
        k_dec = kb * jnp.exp(PC - P)
        S = jnp.exp(PC[:, 0])[..., None] * S + jnp.einsum("bshk,bshv->bhkv", k_dec, vb)
        return S, o

    state, o = jax.lax.scan(chunk_step, state, (rc, kc, vc, ldc),
                            unroll=n if unroll else 1)
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D)
    return o.astype(r.dtype), state


def time_mix(p, x, *, head_size: int, state=None, shift_prev=None, chunked=True,
             chunk: int = 64, unroll: bool = False):
    """Full RWKV6 time-mix block. Returns (out, (state, last_token))."""
    B, T, d = x.shape
    H = d // head_size
    if state is None:
        state = jnp.zeros((B, H, head_size, head_size), jnp.float32)
    x_prev = _token_shift(x, shift_prev)
    r, k, v, g, log_decay = _project(p, x, x_prev, head_size)
    bonus = p["bonus"].astype(jnp.float32)
    if chunked and T > 1:
        o, state = wkv_chunked(r, k, v, log_decay, bonus, state, chunk=chunk,
                               unroll=unroll)
    else:
        o, state = wkv_recurrent(r, k, v, log_decay, bonus, state)
    o = groupnorm_heads(p["ln_x_w"], p["ln_x_b"], o)
    o = (o.reshape(B, T, d) * g.reshape(B, T, d)) @ p["w_o"]
    return o, (state, x[:, -1:])


def channel_mix(p, x, *, shift_prev=None):
    """RWKV6 channel-mix (squared-ReLU FFN with token shift + receptance gate)."""
    x_prev = _token_shift(x, shift_prev)
    dx = (x_prev - x).astype(jnp.float32)
    mu = p["cm_mu"][None, None]                                  # (1,1,2,d)
    xk = (x.astype(jnp.float32) + dx * mu[:, :, 0]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + dx * mu[:, :, 1]).astype(x.dtype)
    kk = jnp.square(jax.nn.relu((xk @ p["cm_k"]).astype(jnp.float32))).astype(x.dtype)
    rr = jax.nn.sigmoid((xr @ p["cm_r"]).astype(jnp.float32)).astype(x.dtype)
    return rr * (kk @ p["cm_v"]), x[:, -1:]
