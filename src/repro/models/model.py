"""Model-level API: train_step, prefill_step, serve_step (decode), and the
abstract input/param/cache specs the multi-pod dry-run lowers against.

Every entry point is a pure function of (params, batch [, caches, opt_state])
so that ``jax.jit(...).lower(...)`` with ``ShapeDtypeStruct`` stand-ins never
allocates — the dry-run contract (DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer
from repro.models.layers import cross_entropy_loss
from repro.models.param import (
    ParamDef, abstract_tree, count_params, init_tree, physical_spec, sharding_tree,
)
from repro.models.transformer import ArchConfig
from repro.optim.adamw import AdamWState, adamw_update, cosine_lr


# ---------------------------------------------------------------------------
# Shapes (the assigned benchmark cells)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
    # reduced shapes for CPU smoke tests
    "smoke_train": ShapeSpec("smoke_train", 32, 2, "train"),
    "smoke_prefill": ShapeSpec("smoke_prefill", 32, 2, "prefill"),
    "smoke_decode": ShapeSpec("smoke_decode", 32, 2, "decode"),
}


def cell_is_supported(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """The (arch x shape) gate: long_500k runs for SSM/hybrid/linear-attn
    families (their decode state is O(1) or sequence-sharded); pure
    full-attention archs skip it per the assignment (see DESIGN.md §6)."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid") or cfg.sub_quadratic:
            return True, ""
        return False, (
            "long_500k requires sub-quadratic token mixing; "
            f"{cfg.name} ({cfg.family}) is full-attention (skip per spec, DESIGN.md §6)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec, mesh=None) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, T = shape.global_batch, shape.seq_len

    def sds(shp, dtype, axes):
        sh = NamedSharding(mesh, physical_spec(shp, axes, mesh)) if mesh is not None else None
        return jax.ShapeDtypeStruct(shp, dtype, sharding=sh)

    batch: dict = {}
    if shape.kind in ("train", "prefill"):
        t_text = T - (cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0)
        batch["tokens"] = sds((B, t_text), jnp.int32, ("batch", "seq"))
        if shape.kind == "train":
            batch["labels"] = sds((B, t_text), jnp.int32, ("batch", "seq"))
            batch["loss_mask"] = sds((B, t_text), jnp.float32, ("batch", "seq"))
        if cfg.frontend == "vlm":
            batch["patch_embeds"] = sds((B, cfg.n_frontend_tokens, cfg.d_model),
                                        cfg.dtype, ("batch", "seq", "d_model"))
        if cfg.enc_dec:
            enc_len = T if shape.kind == "train" else min(T, 4 * cfg.enc_len_decode)
            batch["frames"] = sds((B, enc_len, cfg.d_model), cfg.dtype,
                                  ("batch", "seq", "d_model"))
    else:  # decode
        batch["token"] = sds((B, 1), jnp.int32, ("batch", None))
    return batch


def abstract_params(cfg: ArchConfig, mesh=None) -> dict:
    return abstract_tree(transformer.build_model_defs(cfg), mesh)


def abstract_caches(cfg: ArchConfig, shape: ShapeSpec, mesh=None) -> dict:
    return abstract_tree(transformer.build_cache_defs(cfg, shape.global_batch,
                                                      shape.seq_len), mesh)


def param_shardings(cfg: ArchConfig, mesh) -> dict:
    return sharding_tree(transformer.build_model_defs(cfg), mesh)


def n_params(cfg: ArchConfig) -> int:
    return count_params(transformer.build_model_defs(cfg))


def n_active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: routed experts counted at top_k/E)."""
    defs = transformer.build_model_defs(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            defs, is_leaf=lambda x: isinstance(x, ParamDef))[0]:
        size = math.prod(leaf.shape)
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        if "moe" in keys and any(s in keys for s in ("w_gate", "w_up", "w_down")):
            size = size * cfg.top_k // max(cfg.n_experts, 1)
        total += size
    return total


# ---------------------------------------------------------------------------
# Real initialization (smoke scale)
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    return init_tree(transformer.build_model_defs(cfg), key)


def init_caches(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return init_tree(transformer.build_cache_defs(cfg, batch, max_len),
                     jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def loss_fn(cfg: ArchConfig, params: dict, batch: dict, *,
            aux_weight: float = 0.01, loss_chunks: int = 8):
    """Chunked-over-sequence loss: the (tokens x vocab) logits never
    materialize in full — gemma3-class vocabs (262k) at 65k tokens/chip would
    otherwise dominate the memory footprint (EXPERIMENTS §Dry-run).  The
    chunk loop is a python loop (exact under the probe cost accounting)."""
    hidden, _, aux = transformer.forward(cfg, params, batch, mode="train",
                                         return_logits=False)
    if cfg.frontend == "vlm":
        hidden = hidden[:, batch["patch_embeds"].shape[1]:]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(batch["labels"], jnp.float32)

    T = hidden.shape[1]
    n = loss_chunks
    while T % n:
        n -= 1
    csz = T // n
    num = jnp.float32(0.0)
    for i in range(n):
        sl = slice(i * csz, (i + 1) * csz)
        logits_c = transformer.apply_head(cfg, params, hidden[:, sl])
        num = num + cross_entropy_loss(
            logits_c, batch["labels"][:, sl], mask[:, sl]) \
            * jnp.maximum(mask[:, sl].sum(), 1.0)
    loss = num / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux, (loss, aux)


def make_train_step(cfg: ArchConfig, *, lr_peak: float = 3e-4, total_steps: int = 10000):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamWState, batch):
        (total, (loss, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        lr = cosine_lr(opt_state.count, peak=lr_peak, total=total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total,
                   "lr": lr, "grad_step": opt_state.count}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: ShapeSpec):
    def prefill_step(params, batch, caches):
        hidden, caches, _ = transformer.forward(cfg, params, batch,
                                                mode="prefill", caches=caches,
                                                return_logits=False)
        # vocab projection for the LAST position only (the one serving needs)
        logits = transformer.apply_head(cfg, params, hidden[:, -1:])
        return logits, caches

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """One decode step: next-token logits + updated caches (the paper's
    'iteration' — state sweep + tiny global reduction, cf. DESIGN.md §6)."""

    def serve_step(params, batch, caches):
        logits, caches, _ = transformer.forward(cfg, params, batch,
                                                mode="decode", caches=caches)
        return logits, caches

    return serve_step


def probe_config(cfg: ArchConfig, k_periods: int, seq_len: int | None = None) -> ArchConfig:
    """Cost-probe twin: k periods, python-unrolled layers, unrolled inner scans.

    XLA's cost analysis counts while-loop bodies ONCE, so a scanned model's
    flops/bytes/collectives are undercounted by the trip count.  The dry-run
    therefore compiles unrolled 1-period and 2-period probes whose difference
    is the exact per-period cost; the full-depth scanned compile is still what
    proves memory fit and sharding coherence (EXPERIMENTS.md §Dry-run).
    Inner loops (flash KV blocks, RWKV chunks) are fully unrolled
    (``inner_unroll``); the flash block is coarsened to seq/4 to bound probe
    HLO size (flash cost is block-size invariant).
    """
    T = seq_len or (1 << 15)
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}_probe{k_periods}",
        n_layers=k_periods * len(cfg.period),
        n_enc_layers=k_periods if cfg.enc_dec else 0,
        unroll=True,
        remat=False,
        attn_block=max(1024, T // 4),
        inner_unroll=True,
    )


def abstract_opt_state(cfg: ArchConfig, mesh=None) -> AdamWState:
    """Optimizer moments mirror parameter shapes but carry ZeRO-1 shardings
    (param spec + batch axes over the largest free dim): f32 Adam state is
    4x the bf16 params, so it must not replicate over the data axes."""
    from repro.models.param import zero1_spec
    defs = transformer.build_model_defs(cfg)

    def mk(d: ParamDef):
        sh = None
        if mesh is not None:
            sh = NamedSharding(mesh, zero1_spec(d.shape, d.axes, mesh))
        return jax.ShapeDtypeStruct(d.shape, jnp.float32, sharding=sh)

    mu = jax.tree.map(mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))
    nu = jax.tree.map(mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return AdamWState(mu=mu, nu=nu, count=jax.ShapeDtypeStruct((), jnp.int32))


def out_shardings_for_train(cfg: ArchConfig, mesh):
    """(params, opt_state, metrics) shardings: params keep their layout,
    moments keep ZeRO-1, metrics replicated."""
    from repro.models.param import zero1_sharding_tree
    defs = transformer.build_model_defs(cfg)
    ps = param_shardings(cfg, mesh)
    rep = NamedSharding(mesh, P())
    z1 = zero1_sharding_tree(defs, mesh)
    opt = AdamWState(mu=z1, nu=jax.tree.map(lambda s: s, z1), count=rep)
    metrics = {"loss": rep, "aux_loss": rep, "total_loss": rep, "lr": rep,
               "grad_step": rep}
    return ps, opt, metrics
