"""repro: wafer-scale stencil-code computation (Rocki et al. 2020) on TPU pods.

A production-grade JAX framework reproducing and extending "Fast Stencil-Code
Computation on a Wafer-Scale Processor": a distributed BiCGStab solver for
7-point stencil systems with halo-exchange SpMV, latency-optimal reductions
and mixed-precision arithmetic — adapted from the Cerebras CS-1 fabric to a
multi-pod TPU mesh — plus an LM model zoo sharing the same distribution
substrate.
"""

__version__ = "1.0.0"
