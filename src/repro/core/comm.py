"""Communication scheduling: when collectives run relative to compute.

The paper's CS-1 wins because its fabric makes the two communication
patterns of an iterative stencil solve — nearest-neighbor halo transfers
and the scalar AllReduce of the inner products — cost almost nothing
(§IV, Table I).  On commodity fabrics those costs are real, and the only
lever software holds is the *schedule*: issue the transfers early, shrink
their dependent region, and let independent compute run underneath
(Jacquelin et al.'s overlapped stencil algorithm; Belli & De Sensi's
schedule study on the WSE).

This module makes that schedule a first-class, pluggable object:

* :class:`CommSchedule` — a named policy carried by
  :class:`~repro.core.operator.LinearOperator` and selected end to end via
  ``--schedule`` (launch drivers), ``SolverOptions.schedule`` (CFD app) and
  the workload cell configs.

  - ``blocking`` is the paper-faithful streaming form: assemble the full
    halo'd block, then compute every term from it — the apply *depends* on
    every collective.
  - ``overlap`` splits the apply: the depth-r halo exchange is *started*
    first (:func:`start_halo_exchange`), the interior — which needs no halo
    — is computed while the faces are in flight, and only the depth-r
    boundary ring is patched from the exchanged block
    (:func:`boundary_ring_apply`).  The collectives' dependent region is
    minimal, so XLA's latency-hiding scheduler runs them under the interior
    work.  The result is bit-identical to ``blocking``: both paths
    accumulate the same terms in the same (canonical spec) order.

* :func:`scheduled_apply` — the one composition point: every operator
  backend's SpMV is ``scheduled_apply`` with a backend-specific interior
  (pure-jnp shifts for ``spmd``, the fused Pallas kernel for ``pallas``).

The AllReduce side of the schedule lives with the solvers: the pipelined
Krylov variants (``core/solvers/pipelined.py``) restructure the recurrences
so each iteration has exactly one fused AllReduce, the reduction analogue
of ``overlap``.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core.halo import (
    FabricAxes, gather_halo, interior_apply, padded_apply,
)
from repro.core.precision import Policy, F32
from repro.core.stencil import StencilCoeffs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """A named policy for ordering collectives against compute.

    ``overlap_halo`` selects the split interior/boundary-ring apply;
    schedules are value objects so they can ride in static config
    (``SolverOptions``, workload cells) and be compared by name.
    """

    name: str
    overlap_halo: bool

    def __str__(self) -> str:  # CLI/config round-trip
        return self.name


BLOCKING = CommSchedule("blocking", overlap_halo=False)
OVERLAP = CommSchedule("overlap", overlap_halo=True)

#: schedule name -> schedule; launch drivers and configs key off this.
SCHEDULES = {s.name: s for s in (BLOCKING, OVERLAP)}


def get_schedule(schedule, default: CommSchedule = OVERLAP) -> CommSchedule:
    """Normalize a name / CommSchedule / legacy ``overlap`` bool / None."""
    if schedule is None:
        return default
    if isinstance(schedule, CommSchedule):
        return schedule
    if isinstance(schedule, bool):  # legacy overlap= flag
        return OVERLAP if schedule else BLOCKING
    try:
        return SCHEDULES[schedule]
    except KeyError:
        raise KeyError(
            f"unknown comm schedule {schedule!r}; have {sorted(SCHEDULES)}"
        ) from None


# ---------------------------------------------------------------------------
# Halo-exchange phases
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HaloExchange:
    """An issued depth-r halo exchange.

    Holds the r-padded block with halos filled.  The ``ppermute``s are
    issued when this object is constructed; nothing the interior apply
    computes depends on it, so everything between ``start_halo_exchange``
    and the first read of ``padded`` can run under the transfers.
    """

    padded: jax.Array
    radius: int
    shape: tuple[int, ...]          # the unpadded local *mesh* block shape
    n_batch: int = 0                # leading batch axes riding the exchange


def start_halo_exchange(v: jax.Array, fabric: FabricAxes, radius: int, *,
                        corners: bool = False, n_batch: int = 0) -> HaloExchange:
    """Issue the depth-r slab ``ppermute``s and return the in-flight handle.

    With ``n_batch`` leading batch axes, each ppermute message carries the
    slab of every RHS at once (``(B, r, ...)``) — the message count per
    exchange is independent of the batch size.
    """
    obs_metrics.counter("comm.halo_exchanges_traced").inc()
    with obs_trace.span("comm.halo.issue", radius=radius, n_batch=n_batch):
        padded = gather_halo(v, fabric, radius, corners=corners,
                             n_batch=n_batch)
    return HaloExchange(padded, radius, v.shape[n_batch:], n_batch)


def boundary_regions(shape: tuple[int, ...], fabric: FabricAxes,
                     radius: int) -> list[tuple[slice, ...]]:
    """The depth-r slabs of the local block that read halo values: two per
    split fabric axis.  Slabs of different axes overlap at edges/corners;
    patching them with ``set()`` is idempotent there."""
    regions = []
    for axis, name, n in fabric.split_info(len(shape)):
        if name is None or n == 1:
            continue
        for side in (slice(0, radius), slice(shape[axis] - radius, None)):
            regions.append(tuple(side if i == axis else slice(None)
                                 for i in range(len(shape))))
    return regions


def boundary_ring_apply(coeffs: StencilCoeffs, exchange: HaloExchange,
                        u: jax.Array, fabric: FabricAxes, *,
                        policy: Policy = F32) -> jax.Array:
    """Overwrite the boundary ring of ``u`` with halo-correct values.

    ``u`` is an interior apply (zero-Dirichlet); only the depth-r shell
    bordering a split axis can differ, and each of its cells is recomputed
    from the exchanged block with the same term order as the full apply —
    the patched result is bit-identical to the blocking path.
    """
    pre = (slice(None),) * exchange.n_batch
    for reg in boundary_regions(exchange.shape, fabric, exchange.radius):
        u = u.at[pre + reg].set(
            padded_apply(coeffs, exchange.padded, exchange.shape,
                         policy=policy, region=reg).astype(u.dtype))
    return u


# ---------------------------------------------------------------------------
# The composition point
# ---------------------------------------------------------------------------

def scheduled_apply(coeffs: StencilCoeffs, v: jax.Array, fabric: FabricAxes, *,
                    policy: Policy = F32,
                    schedule: CommSchedule | str | None = None,
                    full_fn=None, interior_fn=None,
                    patch_fn=None, fused_fn=None) -> jax.Array:
    """u = A v on the local shard under the given communication schedule.

    This is the one place the schedule's structure lives; backends
    customize only *how* each piece computes, via hooks that default to
    the pure-jnp shifted-window applies:

    * ``full_fn(vp) -> u`` — the blocking apply over the assembled halo'd
      block (the Pallas backend passes its fused kernel);
    * ``interior_fn(v) -> u`` — the zero-Dirichlet local apply run while
      the faces are in flight (no collective inputs allowed; Pallas: the
      kernel on the zero-padded block);
    * ``patch_fn(exchange, u) -> u`` — overwrite the depth-r boundary ring
      from the exchanged block, already cast to the output dtype (Pallas:
      the kernel re-run on the ring slabs, so overlap stays bit-identical
      to its blocking path);
    * ``fused_fn(exchange) -> u`` — the fused boundary-ring epilogue: one
      pass that computes interior *and* ring from the in-flight exchange
      (Pallas: a single kernel launch instead of interior + patches).
      When given, it replaces the interior/patch pair entirely — the
      exchange is still issued first, so the latency-hiding scheduler can
      run independent work (AXPYs, the preconditioner's local sweeps)
      under the transfers even though the SpMV itself now waits on them.
      Selected per-cell by the tuning cache where the autotune sweep says
      it wins (``kernels/stencil_nd/fused.py``).

    For bit-identity across schedules a backend's hooks must accumulate
    terms in the same canonical order (``StencilCoeffs.ordered_items``) as
    each other — the defaults and the Pallas kernel all do, for every
    epilogue form.
    """
    spec = coeffs.spec
    r = spec.radius
    nb = v.ndim - coeffs.ndim       # leading batch (many-RHS) axes
    sched = get_schedule(schedule)

    # Spans here run at *trace* time (scheduled_apply executes under jit's
    # tracer), so they time lowering work and record structure — they insert
    # no ops, which keeps HLO bit-identical with obs on or off.
    if not sched.overlap_halo:
        with obs_trace.span("comm.halo.blocking", stencil=spec.name):
            obs_metrics.counter("comm.halo_exchanges_traced").inc()
            vp = gather_halo(v, fabric, r, corners=spec.needs_corners,
                             n_batch=nb)
            if full_fn is not None:
                return full_fn(vp)
            return padded_apply(coeffs, vp, v.shape,
                                policy=policy).astype(policy.storage)

    exchange = start_halo_exchange(v, fabric, r, corners=spec.needs_corners,
                                   n_batch=nb)
    if fused_fn is not None:
        with obs_trace.span("comm.halo.fused_epilogue", stencil=spec.name):
            return fused_fn(exchange)
    with obs_trace.span("comm.halo.interior", stencil=spec.name):
        if interior_fn is None:
            u = interior_apply(coeffs, v, policy=policy)
        else:
            u = interior_fn(v)
    with obs_trace.span("comm.halo.ring", stencil=spec.name):
        if patch_fn is not None:
            return patch_fn(exchange, u)
        u = boundary_ring_apply(coeffs, exchange, u, fabric, policy=policy)
        return u.astype(policy.storage)
