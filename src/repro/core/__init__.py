"""The paper's primary contribution: distributed stencil BiCGStab.

Layers: stencil operators (stencil.py), fabric halo exchange (halo.py),
the solver loop with precision policies (bicgstab.py, precision.py), the
analytic performance model (perfmodel.py) and the SIMPLE CFD driver
(simple_cfd.py).
"""

from repro.core import bicgstab, halo, precision, stencil  # noqa: F401
