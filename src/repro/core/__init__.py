"""The paper's primary contribution: distributed stencil BiCGStab.

Layers: stencil operators (stencil.py), fabric halo exchange (halo.py),
the pluggable operator backends (operator.py), the solver registry
(solvers/), right preconditioning (precond.py), precision policies
(precision.py), the drivers gluing them together (bicgstab.py), the
analytic performance model (perfmodel.py) and the SIMPLE CFD driver
(simple_cfd.py).
"""

from repro.core import (  # noqa: F401
    bicgstab, halo, operator, precision, precond, solvers, stencil,
)
