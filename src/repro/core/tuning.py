"""Persistent kernel-tuning cache: sweep once, cache winners, look up forever.

The paper's headline number — ~1/3 of machine peak on the 7-point BiCGStab
solve — comes from hand-shaping the per-PE compute to the fabric.  The
Pallas stencil kernels (``kernels/stencil_nd``) instead used one fixed
block shape for every {StencilSpec x dtype x local shape}; Jacquelin et
al.'s scaling study shows block-shape choice dominates achieved bandwidth
for the wide star operators.  This module is the production answer, the
same shape as an inference stack's kernel autotuner:

* :class:`KernelConfig` — one point of the kernel's tuning space: the
  ``(bx, by)`` x/y tile, the Z-split chunk ``zc``, the VMEM-residency
  choice (whole padded block resident vs element-indexed streaming
  windows), and whether the boundary-ring patch of the overlap schedule is
  *fused* into the interior kernel's pass (one launch) or kept as separate
  patch launches.
* :class:`TuningCache` — a JSON-persisted map from a registry-style key
  ``"{spec}/{dtype}/{XxYxZ}"`` to the winning config plus the sweep record
  that chose it.  Default path ``results/tuning_cache.json``; overridden
  (or disabled) by the ``REPRO_TUNING_CACHE`` env var.
* :func:`lookup_config` — the one call sites use: returns the cached
  winner when a valid entry exists, else the deterministic pre-tuning
  default (full-block tile + ``pick_zc`` chunking), so an empty or absent
  cache reproduces the untuned behaviour bit-for-bit.
* :func:`autotune_cell` / :func:`measure_config` — the hypothesis->measure
  sweep primitives ``benchmarks/kernel_autotune.py`` drives (extending the
  ``benchmarks/hillclimb.py`` loop) and ``launch.solve --autotune`` calls
  inline for its own cell.

Kernel imports are deferred inside functions: ``kernels/stencil_nd`` looks
configs up here, so a module-level import would cycle.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp

from repro.core.stencil import StencilSpec

#: default persistence path, relative to the working directory (the repo
#: root in CI and the benchmarks); ``REPRO_TUNING_CACHE`` overrides it.
DEFAULT_CACHE_PATH = os.path.join("results", "tuning_cache.json")

#: ``REPRO_TUNING_CACHE`` values that disable cache lookup entirely.
_DISABLED = ("", "0", "off", "none", "false", "no")

#: modeled peak memory bandwidth (bytes/s) the roofline fractions are
#: quoted against — the same per-chip HBM figure benchmarks/hillclimb.py
#: uses, so before/after tables are comparable across the two harnesses.
PEAK_BYTES_PER_S = 819e9


def _dtype_name(dtype) -> str:
    return jnp.dtype(dtype).name


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point of the stencil kernel's tuning space.

    ``block`` is the (bx, by) x/y tile of the grid (``None`` entries are
    resolved to the full local extent before reaching the kernel); ``zc``
    the Z-split chunk; ``resident`` keeps the whole padded iterate VMEM-
    resident and cuts each grid step's window with ``dynamic_slice``
    (required where Pallas lacks ``pl.Element``); ``fuse_ring`` folds the
    overlap schedule's boundary-ring patch into the interior kernel's pass.
    """

    block: tuple[int, int]
    zc: int
    resident: bool = True
    fuse_ring: bool = False

    def to_json(self) -> dict:
        return {"block": list(self.block), "zc": self.zc,
                "resident": self.resident, "fuse_ring": self.fuse_ring}

    @classmethod
    def from_json(cls, d: dict) -> "KernelConfig":
        return cls(block=tuple(d["block"]), zc=int(d["zc"]),
                   resident=bool(d.get("resident", True)),
                   fuse_ring=bool(d.get("fuse_ring", False)))

    def divides(self, shape: tuple[int, int, int]) -> bool:
        bx, by = self.block
        X, Y, Z = shape
        return X % bx == 0 and Y % by == 0 and Z % self.zc == 0


def cache_key(spec: StencilSpec, dtype, shape: tuple[int, ...]) -> str:
    """Registry-style cache key: ``star7/float32/48x48x32``.

    Stable across processes and jax versions — it names the *problem cell*
    (shape contract x dtype x local block), never the machine or the code
    revision; re-sweep (``kernel_autotune --force``) when either changes.
    """
    dims = "x".join(str(int(s)) for s in shape)
    return f"{spec.name}/{_dtype_name(dtype)}/{dims}"


def nearest_divisor(n: int, want: int) -> int:
    """The largest divisor of ``n`` that is <= ``want`` (>= 1).

    The fallback rule for block shapes that do not evenly divide the local
    block — e.g. the paper's unpadded 600 x 595 tiles, where a requested
    64 x 64 tile degrades to 60 x 35 instead of a cryptic Pallas shape
    error deep inside ``pallas_call``.
    """
    want = max(1, min(int(want), n))
    for d in range(want, 0, -1):
        if n % d == 0:
            return d
    return 1


def validate_config(config: KernelConfig, shape: tuple[int, int, int], *,
                    warn: bool = True, context: str = "") -> KernelConfig:
    """Clamp ``config`` to tile sizes that evenly divide ``shape``.

    Returns the config unchanged when it already divides; otherwise the
    nearest valid shape (largest divisors <= the requested tile) with a
    warning that names both — the trace-time guard the raw kernel assert
    used to leave to Pallas.
    """
    if config.divides(shape):
        return config
    X, Y, Z = shape
    fixed = dataclasses.replace(
        config,
        block=(nearest_divisor(X, config.block[0]),
               nearest_divisor(Y, config.block[1])),
        zc=nearest_divisor(Z, config.zc))
    if warn:
        warnings.warn(
            f"stencil kernel tile {config.block + (config.zc,)} does not "
            f"evenly divide the local block {shape}{context}; falling back "
            f"to the nearest valid tile {fixed.block + (fixed.zc,)}",
            stacklevel=3)
    return fixed


def default_config(spec: StencilSpec, dtype,
                   shape: tuple[int, int, int]) -> KernelConfig:
    """The deterministic pre-tuning default: full-block (bx, by) tile and
    the ``pick_zc`` VMEM-budgeted Z chunk — exactly what the kernel used
    before the tuning cache existed, so a missing cache changes nothing."""
    from repro.compat import HAS_PL_ELEMENT
    from repro.kernels.stencil_nd.ops import pick_zc

    X, Y, Z = shape
    zc = pick_zc(X, Y, Z, jnp.dtype(dtype).itemsize,
                 radius=spec.radius, n_coeffs=spec.n_offsets)
    return KernelConfig(block=(X, Y), zc=zc, resident=not HAS_PL_ELEMENT,
                        fuse_ring=False)


# ---------------------------------------------------------------------------
# The persistent cache
# ---------------------------------------------------------------------------

class TuningCache:
    """A {cache_key -> sweep record} map persisted as one JSON file.

    Each entry holds the winning ``config`` plus the measurement record
    that chose it (candidate timings, default timing, roofline fractions),
    so the cache file doubles as the sweep's results artifact.
    """

    def __init__(self, path: str | None, entries: dict | None = None):
        self.path = path
        self.entries: dict[str, dict] = dict(entries or {})

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        """Load from ``path``; a missing or unreadable file is an empty
        cache (deterministic defaults), never an error."""
        try:
            with open(path) as f:
                raw = json.load(f)
            entries = raw.get("entries", {}) if isinstance(raw, dict) else {}
        except (OSError, ValueError):
            entries = {}
        return cls(path, entries)

    def save(self, path: str | None = None) -> str:
        path = path or self.path or DEFAULT_CACHE_PATH
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = {
            "format": "repro.tuning_cache.v1",
            "generated_by": "repro.core.tuning",
            "peak_bytes_per_s": PEAK_BYTES_PER_S,
            "entries": {k: self.entries[k] for k in sorted(self.entries)},
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        self.path = path
        return path

    def get(self, key: str) -> KernelConfig | None:
        entry = self.entries.get(key)
        if entry is None:
            return None
        try:
            return KernelConfig.from_json(entry["config"])
        except (KeyError, TypeError, ValueError):
            return None

    def put(self, key: str, config: KernelConfig, record: dict | None = None):
        self.entries[key] = {"config": config.to_json(), **(record or {})}

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def __len__(self) -> int:
        return len(self.entries)


def resolve_cache_path() -> str | None:
    """The active cache path: ``REPRO_TUNING_CACHE`` (a path, or one of
    ``0/off/none`` to disable lookup) falling back to the default."""
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env is None:
        return DEFAULT_CACHE_PATH
    if env.strip().lower() in _DISABLED:
        return None
    return env


# (path -> (mtime, cache)) memo so trace-time lookups don't re-read the
# file per call; a saved cache bumps the mtime and is picked up again.
_LOADED: dict[str, tuple[float, TuningCache]] = {}


def get_cache(path: str | None = None) -> TuningCache | None:
    """The active :class:`TuningCache`, or None when lookup is disabled."""
    path = path if path is not None else resolve_cache_path()
    if path is None:
        return None
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        mtime = -1.0
    hit = _LOADED.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    cache = TuningCache.load(path)
    _LOADED[path] = (mtime, cache)
    return cache


def lookup_config(spec: StencilSpec, dtype, shape: tuple[int, int, int], *,
                  cache: TuningCache | None = None,
                  ) -> tuple[KernelConfig, str]:
    """The call every kernel wrapper makes: ``(config, source)``.

    ``shape`` may carry leading batch axes (a many-RHS apply); only the
    trailing mesh dims key the lookup — a cell tuned at ``(bx, by, Z)``
    serves every batch size, since the kernel's per-step working set is
    one RHS's tile either way.

    ``source`` is ``"cache"`` for a valid tuned entry, ``"default"`` when
    the cache is disabled/missing/has no entry, and ``"stale"`` when an
    entry exists but names a tile that no longer divides ``shape`` (the
    deterministic default is used, with a warning) — so tests and CI can
    assert lookups do not silently regress to defaults.
    """
    from repro.obs import metrics as obs_metrics

    shape = tuple(shape)[-3:]
    cache = cache if cache is not None else get_cache()
    key = cache_key(spec, dtype, shape)
    if cache is not None:
        tuned = cache.get(key)
        if tuned is not None:
            if tuned.divides(shape):
                obs_metrics.counter("tuning.lookup.cache").inc()
                return tuned, "cache"
            warnings.warn(
                f"tuning-cache entry {key!r} names tile "
                f"{tuned.block + (tuned.zc,)} which does not divide the "
                f"local block {shape} (stale entry?); using the default "
                f"config — re-sweep with benchmarks/kernel_autotune.py",
                stacklevel=2)
            obs_metrics.counter("tuning.lookup.stale").inc()
            return default_config(spec, dtype, shape), "stale"
    obs_metrics.counter("tuning.lookup.default").inc()
    return default_config(spec, dtype, shape), "default"


# ---------------------------------------------------------------------------
# The sweep primitives (hypothesis -> measure, hillclimb-style)
# ---------------------------------------------------------------------------

def candidate_configs(spec: StencilSpec, dtype,
                      shape: tuple[int, int, int], *,
                      smoke: bool = False) -> list[KernelConfig]:
    """The sweep's hypothesis set for one cell, deduplicated and valid.

    Axes: (bx, by) x/y tiles (full block plus halves/quarters), Z-split
    factors around the VMEM-budgeted default, VMEM-residency (streaming
    windows only where ``pl.Element`` exists), and ring fusion.  The
    deterministic default is always candidate 0 so the sweep's "before"
    column is measured under the same harness as every hypothesis.
    """
    from repro.compat import HAS_PL_ELEMENT

    X, Y, Z = shape
    base = default_config(spec, dtype, shape)
    divs = (1, 2) if smoke else (1, 2, 4)
    blocks = {(nearest_divisor(X, X // d), nearest_divisor(Y, Y // e))
              for d in divs for e in divs}
    zcs = {base.zc, nearest_divisor(Z, Z), nearest_divisor(Z, max(1, Z // 2))}
    if not smoke:
        zcs.add(nearest_divisor(Z, max(1, Z // 4)))
    residents = (True, False) if HAS_PL_ELEMENT else (True,)
    cands = [base]
    for blk in sorted(blocks, reverse=True):
        for zc in sorted(zcs, reverse=True):
            for res in residents:
                for fuse in (False, True):
                    c = KernelConfig(block=blk, zc=zc, resident=res,
                                     fuse_ring=fuse)
                    if c != base and c.divides(shape):
                        cands.append(c)
    return cands


def _cell_problem(spec: StencilSpec, dtype, shape: tuple[int, int, int]):
    """Deterministic coefficients + iterate for timing one cell."""
    from repro.core import stencil

    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape,
                                     dtype=dtype, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(1), shape,
                          jnp.float32).astype(dtype)
    return cf, v


def synthetic_exchange(v: jax.Array, spec: StencilSpec, fabric):
    """A collective-free stand-in for an issued depth-r halo exchange.

    Mimics ``gather_halo``'s layout exactly: the padded interior is ``v``
    bit-for-bit, the halo slabs of every *split* fabric axis carry values
    (random, standing in for a neighbor's face), and unsplit-axis halos
    stay zero (the global Dirichlet boundary).  That layout is what the
    fused-vs-split bitwise identity rests on — a non-ring cell must read
    the same (zero) unsplit-axis halo in both forms.
    """
    from repro.core import comm

    r = spec.radius
    vp = jnp.pad(v, r)
    key = jax.random.PRNGKey(2)
    for axis, name, n in fabric.split_info(v.ndim):
        if name is None or n == 1:
            continue
        for side in (slice(0, r), slice(vp.shape[axis] - r, None)):
            reg = tuple(side if i == axis else slice(None)
                        for i in range(v.ndim))
            key, sub = jax.random.split(key)
            vp = vp.at[reg].set(
                jax.random.normal(sub, vp[reg].shape,
                                  jnp.float32).astype(vp.dtype))
    return comm.HaloExchange(padded=vp, radius=r, shape=v.shape)


def spmv_bytes(spec: StencilSpec, dtype, shape: tuple[int, int, int]) -> int:
    """HBM traffic of one fused SpMV pass: each coefficient diagonal read
    once, v read once, u written once (the kernel's streaming contract)."""
    n = 1
    for s in shape:
        n *= int(s)
    return (spec.n_offsets + 2) * n * jnp.dtype(dtype).itemsize


def measure_config(spec: StencilSpec, dtype, shape: tuple[int, int, int],
                   config: KernelConfig, *, repeats: int = 3,
                   interpret: bool | None = None) -> float:
    """Median wall seconds of one kernel apply under ``config``.

    ``fuse_ring=False`` times the overlap schedule's split form — the
    interior kernel plus the per-region boundary-ring patch launches;
    ``fuse_ring=True`` the fused form — one pass over the exchanged block.
    Both are timed against the same synthetic exchanged halo (no
    collectives; the schedule's compute cost is what differs).
    """
    from repro.core import comm
    from repro.core.halo import FabricAxes
    from repro.kernels.stencil_nd.ops import ring_patch_apply, tile_apply

    cf, v = _cell_problem(spec, dtype, shape)
    cf_list = [cf.diags[n] for n in spec.names]
    r = spec.radius
    # synthetic in-flight exchange: halo slabs filled, no ppermutes
    fabric = FabricAxes(nx=2, ny=2)   # shape-only: both x/y axes "split"
    exchange = synthetic_exchange(v, spec, fabric)
    vp = exchange.padded

    if config.fuse_ring:
        def apply_once(vpad):
            return tile_apply(vpad, cf_list, spec, config,
                              interpret=interpret)
        fn = jax.jit(apply_once)
        args = (vp,)
    else:
        def apply_once(vv, vpad):
            u = tile_apply(jnp.pad(vv, r), cf_list, spec, config,
                           interpret=interpret)
            ex = comm.HaloExchange(padded=vpad, radius=r, shape=vv.shape)
            return ring_patch_apply(ex, cf_list, spec, config, u, fabric,
                                    interpret=interpret)
        fn = jax.jit(apply_once)
        args = (v, vp)

    jax.block_until_ready(fn(*args))          # compile + warm
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune_cell(spec: StencilSpec, dtype, shape: tuple[int, int, int], *,
                  cache: TuningCache | None = None, force: bool = False,
                  smoke: bool = False, repeats: int = 3,
                  interpret: bool | None = None, save: bool = True) -> dict:
    """Sweep one {spec x dtype x shape} cell and persist the winner.

    A valid cached entry short-circuits the sweep (``cache_hit`` True,
    identical winner) unless ``force``.  Returns the cell record: winner
    config, per-candidate timings, the fixed-default baseline, and the
    roofline fraction before/after (bytes moved per :func:`spmv_bytes`
    against :data:`PEAK_BYTES_PER_S`).
    """
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    cache = cache if cache is not None else get_cache()
    if cache is None:
        cache = TuningCache(resolve_cache_path() or DEFAULT_CACHE_PATH)
    key = cache_key(spec, dtype, shape)
    cached = cache.get(key)
    if cached is not None and not force and cached.divides(shape):
        obs_metrics.counter("tuning.sweep.cache_hit").inc()
        rec = dict(cache.entries[key])
        rec.update(key=key, cache_hit=True)
        return rec

    obs_metrics.counter("tuning.sweep.runs").inc()
    cands = candidate_configs(spec, dtype, shape, smoke=smoke)
    bytes_moved = spmv_bytes(spec, dtype, shape)
    swept = []
    with obs_trace.span("tuning.autotune_cell", key=key,
                        n_candidates=len(cands)):
        for cfg in cands:
            t = measure_config(spec, dtype, shape, cfg, repeats=repeats,
                               interpret=interpret)
            swept.append({"config": cfg.to_json(), "seconds": t,
                          "roofline_frac": bytes_moved / t / PEAK_BYTES_PER_S})
    default_s = swept[0]["seconds"]           # candidate 0 is the default
    best = min(swept, key=lambda s: s["seconds"])
    winner = KernelConfig.from_json(best["config"])
    record = {
        "key": key, "cache_hit": False,
        "shape": list(shape), "spec": spec.name,
        "dtype": _dtype_name(dtype),
        "default_config": cands[0].to_json(),
        "default_seconds": default_s,
        "best_seconds": best["seconds"],
        "speedup_vs_default": default_s / best["seconds"],
        "roofline_frac_default": bytes_moved / default_s / PEAK_BYTES_PER_S,
        "roofline_frac_tuned": best["roofline_frac"],
        "spmv_bytes": bytes_moved,
        "n_candidates": len(swept),
        "swept": swept,
    }
    cache.put(key, winner, record)
    if save:
        cache.save()
    obs_metrics.event("autotune_sweep", key=key,
                      best_seconds=best["seconds"],
                      speedup_vs_default=record["speedup_vs_default"],
                      roofline_frac_tuned=best["roofline_frac"])
    rec = dict(cache.entries[key])
    rec.update(key=key, cache_hit=False)
    return rec


def ensure_tuned(spec: StencilSpec, dtype, shape: tuple[int, int, int], *,
                 smoke: bool = True, interpret: bool | None = None) -> dict:
    """``launch.solve --autotune``'s entry: sweep the cell only when no
    valid cache entry exists, then return the entry (a pure lookup hit on
    every later run)."""
    return autotune_cell(spec, dtype, shape, smoke=smoke,
                         interpret=interpret)
