"""Legacy import surface for the SIMPLE CFD solver (seed API).

The implementation moved to :mod:`repro.apps.cfd` — a full application
subsystem whose inner solves run through the operator/solver/preconditioner
registries (the same pattern as ``core/bicgstab.py`` after the solver-stack
refactor: the algorithm lives elsewhere, the historical names keep working).

``simple_step`` / ``solve_cavity`` keep the seed's staggered-array
signatures; new code should import from ``repro.apps.cfd`` and use the
cell-shaped state + ``SolverOptions`` directly.
"""

from __future__ import annotations

from repro.apps.cfd import (  # noqa: F401
    CavityConfig, CFDConfig, SolverOptions, centerline_u, simple_step,
    solve_cavity, solve_steady,
)

__all__ = [
    "CavityConfig", "CFDConfig", "SolverOptions", "centerline_u",
    "simple_step", "solve_cavity", "solve_steady",
]
