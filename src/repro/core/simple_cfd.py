"""SIMPLE pressure-velocity coupling on a staggered grid (paper §VI, Alg. 2).

The paper sketches MFIX's segregated solver: per outer iteration, form and
BiCGStab-solve the u/v momentum systems, then a pressure-correction
(continuity) system, then under-relaxed field updates — with the linear
solves (this repo's core) taking 50-70% of the work and the matrix forming
the rest (paper Table II).

This is a faithful 2D incompressible instance of Alg. 2:

  staggered MAC grid, first-order upwind + central diffusion (the paper's
  "first order upwinding is the most common scheme"), Jacobi-preconditioned
  5-point stencil systems handed to repro.core.bicgstab, SIMPLE p' equation
  with d = A/aP, under-relaxation (alpha_u, alpha_p).

Validated on the lid-driven cavity against Ghia et al. (1982) centerline
values at Re=100 (tests/test_cfd.py) — the same flow the paper's Joule
benchmark runs (Figs. 7-8).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import bicgstab
from repro.core.precision import Policy, F32
from repro.core.stencil import StencilCoeffs


@dataclasses.dataclass
class CavityConfig:
    n: int = 32                 # cells per side
    reynolds: float = 100.0
    lid_velocity: float = 1.0
    alpha_u: float = 0.7        # momentum under-relaxation
    alpha_p: float = 0.3        # pressure under-relaxation
    outer_iters: int = 200
    inner_tol: float = 1e-4     # paper: solver limited to a few iterations
    inner_iters_mom: int = 5    # paper: "limited to 5 iterations for transport"
    inner_iters_p: int = 20     # paper: "20 for continuity"
    tol: float = 1e-5
    policy: Policy = F32


def _upwind_coeffs(Fe, Fw, Fn, Fs, De, Dw, Dn, Ds):
    aE = De + jnp.maximum(-Fe, 0.0)
    aW = Dw + jnp.maximum(Fw, 0.0)
    aN = Dn + jnp.maximum(-Fn, 0.0)
    aS = Ds + jnp.maximum(Fs, 0.0)
    aP = aE + aW + aN + aS + (Fe - Fw) + (Fn - Fs)
    return aP, aE, aW, aN, aS


def _solve_unit_diag(aP, aE, aW, aN, aS, b, x0, cfg: CavityConfig, iters: int):
    """Jacobi-precondition to unit diagonal and hand to BiCGStab.

    Matrix row: aP x_P - aE x_E - aW x_W - aN x_N - aS x_S = b.
    Unit-diagonal off-diagonals are -a_nb/aP (sign folded into coeffs).
    """
    aP = jnp.maximum(aP, 1e-12)
    coeffs = StencilCoeffs({
        "xp": -aE / aP, "xm": -aW / aP,
        "yp": -aN / aP, "ym": -aS / aP,
    })
    res = bicgstab.solve_ref(coeffs, b / aP, x0=x0, tol=cfg.inner_tol,
                             maxiter=iters, policy=cfg.policy)
    return res.x


def simple_step(cfg: CavityConfig, u, v, p):
    """One SIMPLE outer iteration. u: (n+1, n); v: (n, n+1); p: (n, n).

    Returns (u, v, p, continuity_residual, aux dict of momentum residuals).
    """
    n = cfg.n
    h = 1.0 / n
    mu = 1.0 / cfg.reynolds      # rho = 1, U = 1, L = 1
    D = mu                        # D_face = mu * h / h

    # ---- u-momentum (interior faces i=1..n-1) ----------------------------
    # face fluxes interpolated to u-cv faces; ghost rows implement walls/lid
    ue = 0.5 * (u[1:, :] + u[:-1, :])              # (n, ny): east/west flux carriers
    Fe = h * ue[1:, :]                              # for u-cv i=1..n-1
    Fw = h * ue[:-1, :]
    vn = 0.5 * (v[1:, :] + v[:-1, :])               # (n-1, n+1) at u-cv corners
    Fn = h * vn[:, 1:]
    Fs = h * vn[:, :-1]
    aP, aE, aW, aN, aS = _upwind_coeffs(Fe, Fw, Fn, Fs, D, D, D, D)
    # no-slip top/bottom: wall shear via half-cell diffusion, lid adds source
    b = (p[:-1, :] - p[1:, :]) * h                 # pressure force on u-cv
    bottom = jnp.zeros_like(aP).at[:, 0].set(2.0 * D)
    top = jnp.zeros_like(aP).at[:, -1].set(2.0 * D)
    aP = aP + bottom + top                          # wall-ghost folding
    b = b.at[:, -1].add(2.0 * D * cfg.lid_velocity)
    # zero N/S links at walls
    aN = aN.at[:, -1].set(0.0)
    aS = aS.at[:, 0].set(0.0)
    # Patankar in-equation under-relaxation: aP/alpha with old-value anchor —
    # this (not post-hoc mixing) is what keeps the p'<->momentum loop stable.
    aP = aP / cfg.alpha_u
    b = b + (1.0 - cfg.alpha_u) * aP * u[1:-1, :]
    du = h / jnp.maximum(aP, 1e-12)                 # SIMPLE d-coefficient
    u_star_int = _solve_unit_diag(aP, aE, aW, aN, aS, b, u[1:-1, :], cfg,
                                  cfg.inner_iters_mom)
    u_star = u.at[1:-1, :].set(u_star_int)
    mom_res_u = jnp.abs(u_star[1:-1, :] - u[1:-1, :]).max()

    # ---- v-momentum (interior faces j=1..n-1) -----------------------------
    vnn = 0.5 * (v[:, 1:] + v[:, :-1])              # (n, n)
    Fn2 = h * vnn[:, 1:]
    Fs2 = h * vnn[:, :-1]
    uee = 0.5 * (u[:, 1:] + u[:, :-1])              # (n+1, n-1) at v-cv corners
    Fe2 = h * uee[1:, :]
    Fw2 = h * uee[:-1, :]
    aP2, aE2, aW2, aN2, aS2 = _upwind_coeffs(Fe2, Fw2, Fn2, Fs2, D, D, D, D)
    b2 = (p[:, :-1] - p[:, 1:]) * h
    left = jnp.zeros_like(aP2).at[0, :].set(2.0 * D)
    right = jnp.zeros_like(aP2).at[-1, :].set(2.0 * D)
    aP2 = aP2 + left + right
    aE2 = aE2.at[-1, :].set(0.0)
    aW2 = aW2.at[0, :].set(0.0)
    aP2 = aP2 / cfg.alpha_u
    b2 = b2 + (1.0 - cfg.alpha_u) * aP2 * v[:, 1:-1]
    dv = h / jnp.maximum(aP2, 1e-12)
    v_star_int = _solve_unit_diag(aP2, aE2, aW2, aN2, aS2, b2, v[:, 1:-1], cfg,
                                  cfg.inner_iters_mom)
    v_star = v.at[:, 1:-1].set(v_star_int)

    # ---- pressure correction ---------------------------------------------
    # continuity defect of the starred field per cell
    div = (u_star[1:, :] - u_star[:-1, :] + v_star[:, 1:] - v_star[:, :-1]) * h
    # p' coefficients: aE = rho*de*h at interior faces, 0 at boundaries
    dE = jnp.pad(du, ((0, 1), (0, 0)))              # (n, n): face i+1/2 of cell i
    dW = jnp.pad(du, ((1, 0), (0, 0)))
    dN = jnp.pad(dv, ((0, 0), (0, 1)))
    dS = jnp.pad(dv, ((0, 0), (1, 0)))
    aEp = dE * h
    aWp = dW * h
    aNp = dN * h
    aSp = dS * h
    aPp = aEp + aWp + aNp + aSp
    # fix one reference cell (pure Neumann system is singular)
    aPp = aPp.at[0, 0].add(1.0)
    p_corr = _solve_unit_diag(aPp, aEp, aWp, aNp, aSp, -div,
                              jnp.zeros_like(p), cfg, cfg.inner_iters_p)

    # ---- corrections -------------------------------------------------------
    u_new = u_star.at[1:-1, :].add(du * (p_corr[:-1, :] - p_corr[1:, :]))
    v_new = v_star.at[:, 1:-1].add(dv * (p_corr[:, :-1] - p_corr[:, 1:]))
    p_new = p + cfg.alpha_p * p_corr
    cont_res = jnp.abs(div).max()
    return u_new, v_new, p_new, cont_res, {"mom_res_u": mom_res_u}


def solve_cavity(cfg: CavityConfig):
    """Run SIMPLE to convergence. Returns (u, v, p, history of residuals)."""
    n = cfg.n
    u = jnp.zeros((n + 1, n), jnp.float32)
    v = jnp.zeros((n, n + 1), jnp.float32)
    p = jnp.zeros((n, n), jnp.float32)
    step = jax.jit(functools.partial(simple_step, cfg))
    history = []
    for i in range(cfg.outer_iters):
        u, v, p, res, aux = step(u, v, p)
        history.append(float(res))
        if history[-1] < cfg.tol:
            break
    return u, v, p, history


def centerline_u(u: jax.Array) -> jax.Array:
    """u along the vertical centerline (for Ghia et al. comparison)."""
    n = u.shape[1]
    return u[u.shape[0] // 2, :]
