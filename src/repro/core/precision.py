"""Floating-point precision policies (paper §IV-3, §VI-B, Table I).

The CS-1 runs the solver in fp16 with a hardware FMAC that multiplies in
fp16 and accumulates in fp32 without rounding the product.  TPUs have no
fast IEEE-fp16 path; the native 16-bit type is bfloat16, so the adapted
policy is:

* ``storage``  — dtype of the distributed state (x, r, p, q, s, y, coeffs)
* ``compute``  — dtype of elementwise work (stencil products, AXPYs)
* ``reduce``   — dtype of inner-product accumulation and of the AllReduce

``MIXED`` reproduces the paper's half/single split (Table I: 18 HP adds,
22 HP muls, 4 SP adds per meshpoint per iteration); ``F32`` is the paper's
single-precision reference; ``BF16_PURE`` is the all-16-bit ablation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    storage: jnp.dtype
    compute: jnp.dtype
    reduce: jnp.dtype

    def cast_storage(self, tree):
        return jax.tree.map(lambda a: a.astype(self.storage), tree)

    def dot(self, a: jax.Array, b: jax.Array) -> jax.Array:
        """Local inner product with the paper's FMAC semantics.

        Products are formed from ``compute``-dtype operands but accumulated in
        ``reduce`` dtype without intermediate rounding — the exact analogue of
        the CS-1 FMAC ("no rounding of the product prior to the add") is
        ``dot_general`` with ``preferred_element_type``.
        """
        a = a.astype(self.compute).reshape(-1)
        b = b.astype(self.compute).reshape(-1)
        return jax.lax.dot_general(
            a, b, (((0,), (0,)), ((), ())),
            preferred_element_type=self.reduce,
        )

    def norm2(self, a: jax.Array) -> jax.Array:
        """||a||^2 with reduce-dtype accumulation."""
        return self.dot(a, a)


F32 = Policy("f32", jnp.dtype(jnp.float32), jnp.dtype(jnp.float32), jnp.dtype(jnp.float32))
MIXED = Policy("bf16_mixed", jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float32))
BF16_PURE = Policy("bf16_pure", jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.bfloat16))
F64 = Policy("f64", jnp.dtype(jnp.float64), jnp.dtype(jnp.float64), jnp.dtype(jnp.float64))

POLICIES = {p.name: p for p in (F32, MIXED, BF16_PURE, F64)}


def get_policy(name: str) -> Policy:
    try:
        policy = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown precision policy {name!r}; have {sorted(POLICIES)}") from None
    if name == "f64" and not jax.config.jax_enable_x64:
        raise RuntimeError(
            "policy 'f64' needs 64-bit mode: call "
            "jax.config.update('jax_enable_x64', True) (or set JAX_ENABLE_X64=1) "
            "before building arrays, otherwise every float64 silently degrades "
            "to float32")
    return policy
