"""Distributed BiCGStab (paper Alg. 1, §IV), generic over a LinearOperator.

The loop body is written once against the operator protocol and runs in
three modes that share every line of algorithm logic:

* reference: ``op.apply`` = dense-shift oracle, ``op.dots`` = local dots;
* SPMD:      ``op.apply`` = halo-exchange local apply, ``op.dots`` = psum
  over the fabric — the whole loop lives inside one ``shard_map`` so the
  collective schedule (this paper's subject) is exactly what we write;
* Pallas:    when the operator carries :class:`~repro.core.operator.FusedOps`
  the step switches to the fused-kernel dataflow — SpMV kernels plus fused
  update+dot passes producing *local partials*, reduced with
  ``op.reduce_partials`` so one iteration is exactly 3 AllReduces.

Reduction schedule per iteration (paper counts 4 dot products):

    s = A p;                <r0, s>                      (sync point 1)
    y = A q;                <q, y>, <y, y>               (sync point 2)
    r+ = q - w y;           <r0, r+>, <r+, r+>           (sync point 3)

With fused reductions each sync point is one AllReduce => 3/iter; the
paper-faithful separate schedule is one blocking AllReduce per dot => 5/iter
(incl. the convergence norm).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.precision import Policy, F32
from repro.core.solvers.common import (
    SolveResult, axpy_family, bcast_scalar, convergence_test, finish,
    init_counters, run_krylov, safe_div,
)


def bicgstab_loop(
    apply_A: Callable,
    dots: Callable,
    b,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
    axpy=None,
    axpy2=None,
):
    """The generic algorithm body; composable inside jit/shard_map.

    ``apply_A`` and ``dots`` are bare callables (the pre-operator surface,
    kept because ``solve_refined`` and external callers compose it freely);
    :func:`bicgstab_solver` adapts a LinearOperator onto it.
    """
    default_axpy, default_axpy2 = axpy_family(policy)
    axpy = axpy or default_axpy
    axpy2 = axpy2 or default_axpy2

    b = b.astype(policy.storage)
    if x0 is None:
        x0 = jnp.zeros_like(b)
        r0 = b
    else:
        x0 = x0.astype(policy.storage)
        r0 = axpy(jnp.float32(-1.0), apply_A(x0), b)

    bnorm2, rho0 = dots([(b, b), (r0, r0)], policy)  # one setup sync point
    converged = convergence_test(tol, bnorm2)

    def step(carry):
        i, x, r, p, rho, res2, conv, brk = carry
        s = apply_A(p)
        (r0s,) = dots([(r0, s)], policy)
        alpha, bad1 = safe_div(rho, r0s)
        q = axpy(-alpha, s, r)
        y = apply_A(q)
        qy, yy = dots([(q, y), (y, y)], policy)
        omega, bad2 = safe_div(qy, yy)
        x = axpy2(alpha, p, omega, q, x)
        r_new = axpy(-omega, y, q)
        rho_new, res2_new = dots([(r0, r_new), (r_new, r_new)], policy)
        beta_frac, bad3 = safe_div(rho_new, rho)
        alpha_frac, bad4 = safe_div(alpha, omega)
        beta = beta_frac * alpha_frac
        p = axpy(beta, axpy(-omega, s, p), r_new)
        conv = converged(res2_new)
        brk = bad1 | bad2 | bad3 | bad4
        return i + 1, x, r_new, p, rho_new, res2_new, conv, brk

    conv0 = converged(rho0)
    i0, brk0 = init_counters(conv0)
    init = (i0, x0, r0, r0, rho0, rho0, conv0, brk0)
    final, hist = run_krylov(step, init, maxiter=maxiter, bnorm2=bnorm2,
                             record_history=record_history)
    return finish(final, bnorm2, history=hist)


def bicgstab_fused_loop(
    op,
    b,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
):
    """BiCGStab through the operator's fused Pallas passes (op.fused).

    Per iteration: 2 halo-exchange SpMV kernels, the fused update+dot
    kernels of ``kernels/fused_iter`` (each emitting f32 *local* partials
    alongside its vector output), and exactly three ``op.reduce_partials``
    AllReduces — the end-to-end wiring of the fused schedule into the
    distributed loop.

    ``update_q_dots`` recomputes ``q = r - alpha*s`` inside the kernel pass
    that forms the <q,y>/<y,y> partials: the SpMV needs q *before* y exists,
    so q is first formed inline as the SpMV input (identical arithmetic,
    bitwise-equal result) and the kernel then fuses the recompute with both
    dot partials in a single sweep instead of re-reading q from memory.
    """
    f = op.fused
    assert f is not None, "operator has no fused kernel ops (use bicgstab_loop)"
    st = policy.storage

    b = b.astype(st)
    if x0 is None:
        x0 = jnp.zeros_like(b)
        r0 = b
    else:
        x0 = x0.astype(st)
        r0 = (b.astype(policy.compute)
              - op.apply(x0).astype(policy.compute)).astype(st)

    bnorm2, rho0 = op.reduce_partials(
        [f.dot_partial(b, b), f.dot_partial(r0, r0)])  # one setup AllReduce
    converged = convergence_test(tol, bnorm2)

    def step(carry):
        i, x, r, p, rho, res2, conv, brk = carry
        s = op.apply(p)
        (r0s,) = op.reduce_partials([f.dot_partial(r0, s)])     # AllReduce 1
        alpha, bad1 = safe_div(rho, r0s)
        # SpMV input (kernel-identical); bcast aligns a per-RHS [B] alpha
        q_in = r - bcast_scalar(alpha.astype(st), s) * s
        y = op.apply(q_in)
        q, qy, yy = f.update_q_dots(alpha, r, s, y)
        qy, yy = op.reduce_partials([qy, yy])                   # AllReduce 2
        omega, bad2 = safe_div(qy, yy)
        x, r_new, r0r, rr = f.update_xr_dots(alpha, omega, x, p, q, y, r0)
        rho_new, res2_new = op.reduce_partials([r0r, rr])       # AllReduce 3
        beta_frac, bad3 = safe_div(rho_new, rho)
        alpha_frac, bad4 = safe_div(alpha, omega)
        p = f.update_p(beta_frac * alpha_frac, omega, r_new, p, s)
        conv = converged(res2_new)
        brk = bad1 | bad2 | bad3 | bad4
        return i + 1, x, r_new, p, rho_new, res2_new, conv, brk

    conv0 = converged(rho0)
    i0, brk0 = init_counters(conv0)
    init = (i0, x0, r0, r0, rho0, rho0, conv0, brk0)
    final, hist = run_krylov(step, init, maxiter=maxiter, bnorm2=bnorm2,
                             record_history=record_history)
    return finish(final, bnorm2, history=hist)


def bicgstab_solver(
    op,
    b,
    x0=None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
    precond=None,
) -> SolveResult:
    """Registry entry point: BiCGStab over a LinearOperator.

    Right preconditioning (``A M^-1 y = b``, ``x = M^-1 y``) wraps the
    operator's apply and unwraps the returned iterate; residuals and the
    collective schedule are untouched.  Dispatches to the fused-kernel step
    when the operator provides one.
    """
    from repro.core.precond import warm_start, wrap_right

    wrapped, unwrap = wrap_right(op, precond)
    x0 = warm_start(precond, x0)
    if wrapped.fused is not None:
        res = bicgstab_fused_loop(
            wrapped, b, x0, tol=tol, maxiter=maxiter, policy=policy,
            record_history=record_history)
    else:
        res = bicgstab_loop(
            wrapped.apply, wrapped.dots, b, x0, tol=tol, maxiter=maxiter,
            policy=policy, record_history=record_history)
    return unwrap(res)
