"""Solver registry: Krylov loops generic over a :class:`~repro.core.operator
.LinearOperator`.

Every solver is a function ``(operator, b, x0, *, tol, maxiter, policy,
record_history, precond) -> SolveResult``.  The operator supplies the SpMV
and the reduction schedule (reference / SPMD / Pallas-fused backends — see
``core/operator.py``); the solver supplies the recurrence.  Preconditioning
is applied on the *right* (``A M^-1 y = b, x = M^-1 y``), so the residual,
the convergence test and the per-iteration collective schedule are exactly
those of the unpreconditioned loop.

Adding a solver: write ``my_loop(operator, b, x0, **kw)`` in a new module
using the helpers in ``solvers/common.py``, and register it in
:data:`SOLVERS`.  See docs/architecture.md ("adding a solver/backend").
"""

from __future__ import annotations

from repro.core.solvers.bicgstab import bicgstab_solver
from repro.core.solvers.cg import cg_solver
from repro.core.solvers.common import (
    SolveResult, axpy_family, convergence_test, local_dots, safe_div,
)
from repro.core.solvers.pipelined import (
    pipelined_bicgstab_solver, pipelined_cg_solver,
)

SOLVERS = {
    "bicgstab": bicgstab_solver,
    "cg": cg_solver,
    # single-reduction variants: 1 fused AllReduce per iteration (vs 3 / 2),
    # overlappable with the SpMV — see core/solvers/pipelined.py
    "pipelined_bicgstab": pipelined_bicgstab_solver,
    "pipelined_cg": pipelined_cg_solver,
}


def get_solver(name: str):
    try:
        return SOLVERS[name]
    except KeyError:
        raise KeyError(f"unknown solver {name!r}; have {sorted(SOLVERS)}") from None


__all__ = [
    "SOLVERS", "get_solver", "SolveResult", "safe_div", "axpy_family",
    "convergence_test", "local_dots", "bicgstab_solver", "cg_solver",
    "pipelined_bicgstab_solver", "pipelined_cg_solver",
]
