"""Shared solver plumbing: SolveResult, safe division, the AXPY family, and
the while-loop / history-scan scaffolding every Krylov loop reuses.

Everything here composes inside jit and ``shard_map`` — carries are pytrees
of arrays, control flow is ``lax.while_loop`` (or ``lax.scan`` when a
residual history is recorded).

Batched (many-RHS) solves: every helper is vectorized over an optional
leading batch axis ``B``.  A batched solve carries per-RHS scalars —
``alpha``/``rho``/``res2`` become ``[B]`` arrays, the convergence and
breakdown flags ``[B]`` bools, the iteration counter an ``int32[B]`` — and
:func:`run_krylov` freezes each converged (or broken-down) RHS at its exit
state while the rest keep iterating, so per-RHS iteration counts are exact.
The ``B=1`` batched path is arithmetic-identical (bitwise) to the unbatched
path: the same ops run with a broadcast leading axis of extent 1.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.precision import Policy


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["x", "iterations", "rel_residual", "converged", "breakdown", "history"],
    meta_fields=[],
)
@dataclasses.dataclass
class SolveResult:
    """Uniform solver output (BiCGStab and CG alike — drivers and tests
    treat every registered solver identically)."""

    x: jax.Array
    iterations: jax.Array          # int32 (int32[B] for a batched solve)
    rel_residual: jax.Array        # f32, recurrence residual at exit ([B])
    converged: jax.Array           # bool ([B]): independent per-RHS masks
    breakdown: jax.Array           # bool ([B]): a recurrence denom vanished
    history: jax.Array | None = None  # f32[maxiter(, B)] rel residuals


EPS = 1e-30


def convergence_test(tol: float, bnorm2):
    """The uniform relative-residual predicate: ``res2 <= tol^2 * ||b||^2``.

    Every Krylov loop (generic and pipelined alike) tests its squared
    recurrence residual against the same threshold; sharing the closure
    keeps the convergence semantics identical across the registry instead
    of each loop re-deriving ``tol*tol*bnorm2`` inline.

    The threshold is computed in ``bnorm2``'s dtype: an f64 solve with a
    tolerance below f32 eps must not have ``tol*tol`` rounded (or flushed
    to zero) in float32.  ``bnorm2`` may be batched ([B]); the predicate
    is then elementwise per RHS.
    """
    t = jnp.asarray(tol, dtype=jnp.asarray(bnorm2).dtype)
    thresh = t * t * bnorm2

    def converged(res2):
        return res2 <= thresh

    return converged


def safe_div(num, den):
    """num/den plus a breakdown flag when the denominator vanished.

    Elementwise, so batched ([B]) numerators/denominators get independent
    per-RHS breakdown flags.
    """
    ok = jnp.abs(den) > EPS
    return jnp.where(ok, num / jnp.where(ok, den, 1.0), 0.0), ~ok


def bcast_scalar(a, x):
    """A per-RHS scalar (``[B]`` or 0-d) aligned against ``x`` for broadcast.

    Unbatched scalars pass through untouched; a ``[B]`` scalar against a
    ``(B, ...)`` vector gains trailing singleton axes so ``a * x`` scales
    each RHS by its own coefficient.
    """
    a = jnp.asarray(a)
    if a.ndim == 0 or a.ndim >= jnp.ndim(x):
        return a
    return a.reshape(a.shape + (1,) * (jnp.ndim(x) - a.ndim))


def axpy_family(policy: Policy):
    """AXPY family in compute precision (paper Table I: 6 HP AXPYs/iter)."""
    c = policy.compute

    def axpy(a, x, y):  # y + a*x
        ac = bcast_scalar(jnp.asarray(a).astype(c), x)
        return (y.astype(c) + ac * x.astype(c)).astype(policy.storage)

    def axpy2(a, x, b, y, z):  # z + a*x + b*y
        ac = bcast_scalar(jnp.asarray(a).astype(c), x)
        bc = bcast_scalar(jnp.asarray(b).astype(c), y)
        return (
            z.astype(c) + ac * x.astype(c) + bc * y.astype(c)
        ).astype(policy.storage)

    return axpy, axpy2


def local_partial(a, b, policy: Policy, *, mesh_ndim: int | None = None):
    """One FMAC-style local inner-product partial, batch-aware.

    With ``mesh_ndim`` given, operands whose rank exceeds it carry a
    leading batch axis: each RHS slice gets its own ``policy.dot`` (the
    exact unbatched accumulation order, so ``B=1`` is bitwise identical)
    and the partial becomes a ``[B]`` row.
    """
    nb = 0 if mesh_ndim is None else jnp.ndim(a) - mesh_ndim
    if nb <= 0:
        return policy.dot(a, b)
    return jnp.stack([policy.dot(a[i], b[i]) for i in range(a.shape[0])])


def local_dots(pairs, policy: Policy, *, mesh_ndim: int | None = None):
    """Single-address-space reduction: stack of FMAC-style inner products.

    Batched operands (rank above ``mesh_ndim``) produce ``[B]`` rows, so
    the stack of one sync point is a single ``[k, B]`` array — the shape
    the distributed backends push through one fused AllReduce.
    """
    return jnp.stack(
        [local_partial(a, b, policy, mesh_ndim=mesh_ndim) for a, b in pairs])


def init_counters(conv0):
    """(iteration counter, breakdown flag) shaped like the convergence mask.

    Unbatched loops get the classic ``(int32 0, bool False)`` scalars; a
    batched loop (``conv0`` is ``bool[B]``) gets per-RHS counters/flags so
    :func:`run_krylov` can freeze each RHS independently.
    """
    conv0 = jnp.asarray(conv0)
    if conv0.ndim == 0:
        return jnp.int32(0), jnp.bool_(False)
    return jnp.zeros(conv0.shape, jnp.int32), jnp.zeros(conv0.shape, bool)


def _freeze_select(mask, new, old):
    """Per-leaf ``where(mask, new, old)`` with the mask broadcast from the
    leading (batch) axis — so a ``bool[B]`` mask selects whole RHS slices
    of ``(B, ...)`` leaves and elements of ``[B]`` scalar leaves alike."""
    m = mask
    if jnp.ndim(new) > jnp.ndim(mask):
        m = mask.reshape(mask.shape + (1,) * (jnp.ndim(new) - jnp.ndim(mask)))
    return jnp.where(m, new, old)


def run_krylov(step, init, *, maxiter: int, bnorm2, record_history: bool):
    """Drive a Krylov ``step`` to convergence.

    ``step(carry) -> carry`` advances one iteration; the carry contract is
    ``(i, x, *state, res2, conv, brk)`` — position 0 the iteration counter,
    the last three the squared residual, convergence and breakdown flags.

    Batched solves carry per-RHS flags (``bool[B]``): every iteration the
    step result is merged back per RHS, so a converged (or broken-down)
    RHS freezes at its exit state — its counter stops, its ``x``/residual
    stay put — while the still-active RHS keep iterating.  The loop exits
    only when no RHS remains active.

    Returns the final carry plus (optionally) the f32[maxiter(, B)]
    relative residual history: ``record_history=True`` switches the
    ``while_loop`` for a fixed-length ``scan`` whose inactive iterations
    freeze the carry.
    """
    batched = jnp.ndim(init[-2]) > 0

    if record_history:
        def scan_body(carry, _):
            active = ~(carry[-2] | carry[-1])
            new = step(carry)
            carry = jax.tree.map(
                functools.partial(_freeze_select, active), new, carry)
            rel = jnp.sqrt(carry[-3] / jnp.maximum(bnorm2, EPS))
            return carry, rel

        final, hist = jax.lax.scan(scan_body, init, None, length=maxiter)
        return final, hist

    if batched:
        def masked_step(carry):
            active = ~(carry[-2] | carry[-1])
            return jax.tree.map(
                functools.partial(_freeze_select, active), step(carry), carry)

        def cond(carry):
            i, *_rest, conv, brk = carry
            return jnp.any((i < maxiter) & ~conv & ~brk)

        return jax.lax.while_loop(cond, masked_step, init), None

    def cond(carry):
        i, *_rest, conv, brk = carry
        return (i < maxiter) & ~conv & ~brk

    return jax.lax.while_loop(cond, step, init), None


def finish(carry, bnorm2, history=None) -> SolveResult:
    """Assemble a SolveResult from a run_krylov final carry."""
    i, x, *_rest, res2, conv, brk = carry
    rel = jnp.sqrt(res2 / jnp.maximum(bnorm2, EPS))
    return SolveResult(x, i, rel, conv, brk, history=history)


def emit_solve_metrics(result: SolveResult, *, wall_s: float | None = None,
                       **labels):
    """Per-solve observability emission (iterations, per-RHS convergence,
    residual history) into the :mod:`repro.obs.metrics` registry.

    Safe to call anywhere: under jit/shard_map the result's fields are
    tracers and this silently no-ops — the drivers call it again on the
    concrete result, which is where the numbers actually land.  History
    semantics are solver-agnostic (see ``core/solvers/pipelined``:
    the pipelined loops realign their lag-1 recorded history), so
    ``history[k]`` is always the relative residual after iteration k+1.
    """
    from repro.obs import metrics as obs_metrics

    return obs_metrics.record_solve(result, wall_s=wall_s, **labels)
