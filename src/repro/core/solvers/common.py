"""Shared solver plumbing: SolveResult, safe division, the AXPY family, and
the while-loop / history-scan scaffolding every Krylov loop reuses.

Everything here composes inside jit and ``shard_map`` — carries are pytrees
of arrays, control flow is ``lax.while_loop`` (or ``lax.scan`` when a
residual history is recorded).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.precision import Policy


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["x", "iterations", "rel_residual", "converged", "breakdown", "history"],
    meta_fields=[],
)
@dataclasses.dataclass
class SolveResult:
    """Uniform solver output (BiCGStab and CG alike — drivers and tests
    treat every registered solver identically)."""

    x: jax.Array
    iterations: jax.Array          # int32
    rel_residual: jax.Array        # f32, recurrence residual at exit
    converged: jax.Array           # bool
    breakdown: jax.Array           # bool (a recurrence denominator vanished)
    history: jax.Array | None = None  # f32[maxiter] rel residuals (history mode)


EPS = 1e-30


def convergence_test(tol: float, bnorm2):
    """The uniform relative-residual predicate: ``res2 <= tol^2 * ||b||^2``.

    Every Krylov loop (generic and pipelined alike) tests its squared
    recurrence residual against the same threshold; sharing the closure
    keeps the convergence semantics identical across the registry instead
    of each loop re-deriving ``tol*tol*bnorm2`` inline.
    """
    thresh = jnp.float32(tol) * jnp.float32(tol) * bnorm2

    def converged(res2):
        return res2 <= thresh

    return converged


def safe_div(num, den):
    """num/den plus a breakdown flag when the denominator vanished."""
    ok = jnp.abs(den) > EPS
    return jnp.where(ok, num / jnp.where(ok, den, 1.0), 0.0), ~ok


def axpy_family(policy: Policy):
    """AXPY family in compute precision (paper Table I: 6 HP AXPYs/iter)."""
    c = policy.compute

    def axpy(a, x, y):  # y + a*x
        return (y.astype(c) + a.astype(c) * x.astype(c)).astype(policy.storage)

    def axpy2(a, x, b, y, z):  # z + a*x + b*y
        return (
            z.astype(c) + a.astype(c) * x.astype(c) + b.astype(c) * y.astype(c)
        ).astype(policy.storage)

    return axpy, axpy2


def local_dots(pairs, policy: Policy):
    """Single-address-space reduction: stack of FMAC-style inner products."""
    return jnp.stack([policy.dot(a, b) for a, b in pairs])


def run_krylov(step, init, *, maxiter: int, bnorm2, record_history: bool):
    """Drive a Krylov ``step`` to convergence.

    ``step(carry) -> carry`` advances one iteration; the carry contract is
    ``(i, x, *state, res2, conv, brk)`` — position 0 the iteration counter,
    the last three the squared residual, convergence and breakdown flags.

    Returns the final carry plus (optionally) the f32[maxiter] relative
    residual history: ``record_history=True`` switches the ``while_loop``
    for a fixed-length ``scan`` whose inactive iterations freeze the carry.
    """
    if record_history:
        def scan_body(carry, _):
            active = ~(carry[-2] | carry[-1])
            new = step(carry)
            carry = jax.tree.map(lambda n, o: jnp.where(active, n, o), new, carry)
            rel = jnp.sqrt(carry[-3] / jnp.maximum(bnorm2, EPS))
            return carry, rel

        final, hist = jax.lax.scan(scan_body, init, None, length=maxiter)
        return final, hist

    def cond(carry):
        i, *_rest, conv, brk = carry
        return (i < maxiter) & ~conv & ~brk

    return jax.lax.while_loop(cond, step, init), None


def finish(carry, bnorm2, history=None) -> SolveResult:
    """Assemble a SolveResult from a run_krylov final carry."""
    i, x, *_rest, res2, conv, brk = carry
    rel = jnp.sqrt(res2 / jnp.maximum(bnorm2, EPS))
    return SolveResult(x, i, rel, conv, brk, history=history)
