"""Conjugate gradients (the symmetric/HPCG-flavored comparison solver),
generic over a LinearOperator and with full SolveResult parity.

Per-iteration reduction schedule (2 sync points vs BiCGStab's 3):

    ap = A p;        <p, ap>              (sync point 1)
    r+ = r - a*ap;   <r+, r+>  (norm)     (sync point 2)

Breakdown is flagged when <p, Ap> vanishes (loss of positive-definiteness
— e.g. CG applied to a nonsymmetric stencil) or the rho recurrence
degenerates, mirroring the BiCGStab flags so drivers and tests treat both
solvers uniformly.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.precision import Policy, F32
from repro.core.solvers.common import (
    SolveResult, axpy_family, convergence_test, finish, init_counters,
    run_krylov, safe_div,
)


def cg_loop(
    apply_A: Callable,
    dots: Callable,
    b,
    x0=None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
) -> SolveResult:
    """The algorithm body; composable inside jit/shard_map. Returns SolveResult."""
    axpy, _ = axpy_family(policy)
    b = b.astype(policy.storage)
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b
    else:
        x = x0.astype(policy.storage)
        r = axpy(jnp.float32(-1.0), apply_A(x), b)
    bnorm2, rho0 = dots([(b, b), (r, r)], policy)  # one setup sync point
    converged = convergence_test(tol, bnorm2)

    def step(carry):
        i, x, r, p, rho, conv, brk = carry
        ap = apply_A(p)
        (pap,) = dots([(p, ap)], policy)
        alpha, bad1 = safe_div(rho, pap)
        x = axpy(alpha, p, x)
        r = axpy(-alpha, ap, r)
        (rho_new,) = dots([(r, r)], policy)
        beta, bad2 = safe_div(rho_new, rho)
        p = axpy(beta, p, r)
        conv = converged(rho_new)
        return i + 1, x, r, p, rho_new, conv, brk | bad1 | bad2

    conv0 = converged(rho0)
    i0, brk0 = init_counters(conv0)
    init = (i0, x, r, r, rho0, conv0, brk0)
    final, hist = run_krylov(step, init, maxiter=maxiter, bnorm2=bnorm2,
                             record_history=record_history)
    return finish(final, bnorm2, history=hist)


def cg_solver(
    op,
    b,
    x0=None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
    precond=None,
) -> SolveResult:
    """Registry entry point: CG over a LinearOperator (right-preconditioned).

    Note CG's convergence theory wants A SPD and M^-1 symmetric in the A
    inner product; the Chebyshev preconditioner (a polynomial in A) commutes
    with A and preserves this, Jacobi only when the diagonal is constant.
    """
    from repro.core.precond import warm_start, wrap_right

    wrapped, unwrap = wrap_right(op, precond)
    res = cg_loop(wrapped.apply, wrapped.dots, b, warm_start(precond, x0),
                  tol=tol, maxiter=maxiter, policy=policy,
                  record_history=record_history)
    return unwrap(res)
