"""Pipelined Krylov solvers: one fused AllReduce per iteration.

The generic loops synchronize at every recurrence dependency — BiCGStab 3
times per iteration (fused schedule), CG twice.  On a latency-bound fabric
those blocking reductions dominate (paper §IV-3 measures the CS-1's
AllReduce at 1.5 us *because* the fabric erases them; commodity fabrics
cannot).  The pipelined reformulations here restructure the recurrences so
every inner product of an iteration is formed from vectors already in hand
and reduced in a **single** fused AllReduce:

* :func:`pipelined_cg_loop` — Ghysels & Vanroose's pipelined CG.  The
  iteration's two dots (<r,r>, <w,r>) depend only on the carried vectors,
  not on the matvec ``q = A w``, so the one AllReduce is dependency-free of
  the SpMV and overlaps it outright.  One extra vector recurrence triple
  (z, s, p) trades memory traffic for the hidden latency.

* :func:`pipelined_bicgstab_loop` — single-reduction BiCGStab (the
  Yang-Brent "improved BiCGStab" family).  The alpha-/omega-chained dots
  are expanded through ``q = r - alpha s`` and ``y = z - alpha t`` (with
  ``z = A r``, ``t = A s`` maintained at zero extra SpMVs by the recurrence
  ``s' = z' + beta (s - omega t)``), so all 12 scalar ingredients of one
  iteration reduce in one fused AllReduce — down from 3, overlappable with
  the trailing SpMV pair.  Crucially, the cross-iteration scalars are
  *re-anchored* every reduction: ``rho = <r0, r>`` and the convergence norm
  ``<r, r>`` are fresh dots on the carried residual rather than recurrence
  expansions, so rounding drift cannot accumulate — the trajectory tracks
  classic BiCGStab to rounding level (the expansion survives only inside
  one iteration, for omega and beta).

Both return full :class:`~repro.core.solvers.common.SolveResult` parity
(history / breakdown flags) and run on every operator backend — the
reduction count is asserted from lowered HLO in ``tests/test_solvers.py``.

Two costs are inherent and documented rather than hidden: (1) convergence
is checked on the *carried* residual norm (the new residual's norm is not
known until the next iteration's reduction), so both solvers report one
iteration more than their generic counterparts, and the residual the scan
*records* at iteration k is the lag-1 carried norm.  So that metrics
emission is solver-agnostic, :func:`_align_history` shifts the recorded
history back into the generic solvers' semantics — ``history[k]`` is the
relative residual after iteration k+1 for every registered solver; the
final entry repeats the last *reduced* norm, because the residual after
the very last update is never reduced (that is the lag-1 cost itself).
(2) pipelined CG maintains ``w = A r`` purely by
recurrence, which bounds its attainable accuracy near ``sqrt(eps)`` of the
storage dtype (the classic Ghysels-Vanroose trade-off) — ask it for f32
tolerances of ~1e-5, not 1e-8.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.precision import Policy, F32
from repro.core.solvers.common import (
    SolveResult, axpy_family, convergence_test, finish, init_counters,
    run_krylov, safe_div,
)


def _align_history(hist):
    """Shift the lag-1 recorded history into generic-solver semantics.

    The pipelined scans record the *carried* residual: entry k is the norm
    of the residual after only k updates (entry 0 is ``||r0||``), one slot
    behind the generic loops' "residual after iteration k+1".  Dropping the
    leading entry and repeating the final reduced norm restores parity, so
    ``SolveResult.history[k]`` means the same thing for every solver (and
    ``rel_residual == history[iterations - 1]`` on convergence).  Converged
    entries are frozen by ``run_krylov``, so the repeated tail is exact
    there; on a maxiter exit it repeats the last norm the solver ever saw.
    Batched histories (``[maxiter, B]``) shift along the iteration axis.
    """
    if hist is None:
        return None
    return jnp.concatenate([hist[1:], hist[-1:]], axis=0)


def pipelined_bicgstab_loop(
    apply_A: Callable,
    dots: Callable,
    b,
    x0,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
) -> SolveResult:
    """Single-reduction BiCGStab; composable inside jit/shard_map.

    Carried vectors: x, r, p plus the matvec images ``s = A p``,
    ``z = A r``, ``t = A s``.  Per iteration: one fused 12-dot AllReduce,
    2 SpMVs (``z' = A r'`` and ``t' = A s'`` — same count as classic
    BiCGStab), and 9 AXPY-class updates.
    """
    axpy, axpy2 = axpy_family(policy)
    st = policy.storage

    b = b.astype(st)
    if x0 is None:
        x0 = jnp.zeros_like(b)
        r0 = b
    else:
        x0 = x0.astype(st)
        r0 = axpy(jnp.float32(-1.0), apply_A(x0), b)

    # p0 = r0, so s0 = A p0 doubles as z0 = A r0 — setup costs 2 SpMVs and
    # ONE fused AllReduce (the generic loops' setup was folded to one too).
    s0 = apply_A(r0)
    t0 = apply_A(s0)
    bnorm2, rho0 = dots([(b, b), (r0, r0)], policy)
    converged = convergence_test(tol, bnorm2)

    def step(carry):
        i, x, r, p, s, z, t, res2, conv, brk = carry
        # the single sync point: every scalar this iteration needs, formed
        # from vectors already in hand and reduced in one fused AllReduce.
        # rho and rr are *fresh* dots on the carried residual (re-anchor),
        # so scalar rounding never accumulates across iterations.
        (rho, rr, r0s, r0z, r0t, rz, sz, rt, st_, zz, zt, tt) = dots(
            [(r0, r), (r, r), (r0, s), (r0, z), (r0, t), (r, z), (s, z),
             (r, t), (s, t), (z, z), (z, t), (t, t)], policy)
        alpha, bad1 = safe_div(rho, r0s)
        # <q,y> and <y,y> via q = r - alpha s, y = z - alpha t
        qy = rz - alpha * (sz + rt) + alpha * alpha * st_
        yy = zz - 2.0 * alpha * zt + alpha * alpha * tt
        omega, bad2 = safe_div(qy, yy)
        # <r0,r'> = (rho - alpha<r0,s>) - omega(<r0,z> - alpha<r0,t>);
        # used only for this iteration's beta — next alpha re-anchors
        rho_new = (rho - alpha * r0s) - omega * (r0z - alpha * r0t)
        beta_frac, bad3 = safe_div(rho_new, rho)
        alpha_frac, bad4 = safe_div(alpha, omega)
        beta = beta_frac * alpha_frac
        # vector recurrences (classic BiCGStab updates + the A-image pair)
        q = axpy(-alpha, s, r)
        y = axpy(-alpha, t, z)
        x = axpy2(alpha, p, omega, q, x)
        r_new = axpy(-omega, y, q)
        p_new = axpy(beta, axpy(-omega, s, p), r_new)
        z_new = apply_A(r_new)
        s_new = axpy(beta, axpy(-omega, t, s), z_new)   # s' = A p' for free
        t_new = apply_A(s_new)
        conv = converged(rr)       # ||r||^2 of the carried (lag-1) residual
        brk = bad1 | bad2 | bad3 | bad4
        return (i + 1, x, r_new, p_new, s_new, z_new, t_new, rr, conv, brk)

    conv0 = converged(rho0)
    i0, brk0 = init_counters(conv0)
    init = (i0, x0, r0, r0, s0, s0, t0, rho0, conv0, brk0)
    final, hist = run_krylov(step, init, maxiter=maxiter, bnorm2=bnorm2,
                             record_history=record_history)
    return finish(final, bnorm2, history=_align_history(hist))


def pipelined_cg_loop(
    apply_A: Callable,
    dots: Callable,
    b,
    x0=None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
) -> SolveResult:
    """Ghysels-Vanroose pipelined CG; composable inside jit/shard_map.

    The fused (<r,r>, <w,r>) reduction shares no dependency with the
    iteration's only SpMV ``q = A w``, so the AllReduce genuinely hides
    under the matvec.  Convergence is checked on the carried gamma = <r,r>
    (one iteration lagged — see the module docstring).
    """
    axpy, _ = axpy_family(policy)
    st = policy.storage

    b = b.astype(st)
    if x0 is None:
        x = jnp.zeros_like(b)
        r = b
    else:
        x = x0.astype(st)
        r = axpy(jnp.float32(-1.0), apply_A(x), b)
    w0 = apply_A(r)
    bnorm2, gamma0 = dots([(b, b), (r, r)], policy)
    converged = convergence_test(tol, bnorm2)

    def step(carry):
        i, x, r, w, p, s, z, gamma_old, alpha_old, res2, conv, brk = carry
        gamma, delta = dots([(r, r), (w, r)], policy)    # the one AllReduce
        q = apply_A(w)                                   # overlapped SpMV
        first = i == 0
        beta_raw, badb = safe_div(gamma, gamma_old)
        beta = jnp.where(first, 0.0, beta_raw)
        corr, badc = safe_div(beta * gamma, alpha_old)
        alpha, bada = safe_div(gamma,
                               delta - jnp.where(first, 0.0, corr))
        z = axpy(beta, z, q)            # z = q + beta z   (= A s)
        s = axpy(beta, s, w)            # s = w + beta s   (= A p)
        p = axpy(beta, p, r)            # p = r + beta p
        x = axpy(alpha, p, x)
        r = axpy(-alpha, s, r)
        w = axpy(-alpha, z, w)          # w = A r by recurrence
        conv = converged(gamma)
        brk = brk | bada | (~first & (badb | badc))
        return i + 1, x, r, w, p, s, z, gamma, alpha, gamma, conv, brk

    zeros = jnp.zeros_like(b)
    conv0 = converged(gamma0)
    i0, brk0 = init_counters(conv0)
    # alpha_old shaped like gamma (per-RHS for batched solves) so the
    # while_loop carry structure is shape-stable
    init = (
        i0, x, r, w0, zeros, zeros, zeros,
        gamma0, jnp.ones_like(gamma0), gamma0,
        conv0, brk0,
    )
    final, hist = run_krylov(step, init, maxiter=maxiter, bnorm2=bnorm2,
                             record_history=record_history)
    return finish(final, bnorm2, history=_align_history(hist))


def _right_preconditioned(loop):
    def solver(op, b, x0=None, *, tol: float = 1e-6, maxiter: int = 200,
               policy: Policy = F32, record_history: bool = False,
               precond=None) -> SolveResult:
        from repro.core.precond import warm_start, wrap_right

        wrapped, unwrap = wrap_right(op, precond)
        res = loop(wrapped.apply, wrapped.dots, b, warm_start(precond, x0),
                   tol=tol, maxiter=maxiter, policy=policy,
                   record_history=record_history)
        return unwrap(res)
    return solver


#: Registry entry points (see core/solvers/__init__.py): right-
#: preconditioned like the generic solvers — the collective schedule
#: (1 AllReduce/iter) is untouched by any preconditioner.
pipelined_bicgstab_solver = _right_preconditioned(pipelined_bicgstab_loop)
pipelined_bicgstab_solver.__name__ = "pipelined_bicgstab_solver"
pipelined_cg_solver = _right_preconditioned(pipelined_cg_loop)
pipelined_cg_solver.__name__ = "pipelined_cg_solver"
