"""Halo-exchange SpMV on the chip fabric (paper §IV-1, Figs. 3-5).

The paper's scheme: every core broadcasts its Z-pencil of the iterate to its
four fabric neighbors (one outgoing channel, four incoming channels — the
tessellation coloring of Fig. 5), multiplies the four received pencils with
the stored coefficient diagonals, and handles the two Z-shifted terms from a
local loopback.

TPU adaptation: a chip owns a ``(bx, by, Z)`` sub-volume, not a single
pencil, so only the *faces* of the block move.  The four neighbor channels
become four ``jax.lax.ppermute`` shifts (XLA ``collective-permute`` on the
ICI torus); fabric-edge chips receive zeros from ``ppermute``, which is
exactly the zero-Dirichlet boundary.  The CS-1 FIFO/task overlap machinery
is replaced by dataflow: the interior stencil terms do not depend on the
permutes, so XLA's latency-hiding scheduler runs the collectives under the
interior compute (``overlap=True`` makes this explicit by shrinking the
halo-dependent computation to a rank-1 face update).

All functions here are *local* (rank-per-shard) and must run inside
``jax.shard_map``; :mod:`repro.core.bicgstab` builds the global solver.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.precision import Policy, F32
from repro.core.stencil import StencilCoeffs, _shift


@dataclasses.dataclass(frozen=True)
class FabricAxes:
    """Names/sizes of the mesh axes carrying the stencil's X, Y (and Z) dims."""

    x: str = "data"
    nx: int = 1
    y: str = "model"
    ny: int = 1
    z: str | None = None          # pod axis slabs Z when multi-pod
    nz: int = 1

    @classmethod
    def from_mesh(cls, mesh) -> "FabricAxes":
        ax = dict(zip(mesh.axis_names, mesh.devices.shape))
        return cls(
            x="data", nx=ax["data"], y="model", ny=ax["model"],
            z="pod" if "pod" in ax else None, nz=ax.get("pod", 1),
        )

    def spec(self, ndim: int = 3) -> P:
        """PartitionSpec for a mesh-shaped field (X, Y[, Z])."""
        if ndim == 2:
            return P(self.x, self.y)
        return P(self.x, self.y, self.z)


def _exchange(face_lo, face_hi, axis_name: str, n: int):
    """Bidirectional nearest-neighbor exchange of two faces along one axis.

    Returns ``(from_lo, from_hi)``: the lower neighbor's high face and the
    upper neighbor's low face.  Edge shards receive zeros (Dirichlet).
    """
    if n == 1:
        return jnp.zeros_like(face_hi), jnp.zeros_like(face_lo)
    fwd = [(i, i + 1) for i in range(n - 1)]
    bwd = [(i + 1, i) for i in range(n - 1)]
    from_lo = jax.lax.ppermute(face_hi, axis_name, fwd)   # neighbor i-1 sent its high face
    from_hi = jax.lax.ppermute(face_lo, axis_name, bwd)   # neighbor i+1 sent its low face
    return from_lo, from_hi


def halo_faces(v: jax.Array, fabric: FabricAxes):
    """All neighbor faces of the local block, one ppermute pair per axis.

    This is the communication phase of the paper's SpMV: 2 or 3 bidirectional
    face exchanges, all independent, all overlappable with interior compute.
    """
    faces = {}
    take = lambda a, sl: v[tuple(sl if i == a else slice(None) for i in range(v.ndim))]
    faces["xm"], faces["xp"] = _exchange(take(0, slice(0, 1)), take(0, slice(-1, None)),
                                         fabric.x, fabric.nx)
    faces["ym"], faces["yp"] = _exchange(take(1, slice(0, 1)), take(1, slice(-1, None)),
                                         fabric.y, fabric.ny)
    if v.ndim == 3 and fabric.z is not None:
        faces["zm"], faces["zp"] = _exchange(take(2, slice(0, 1)), take(2, slice(-1, None)),
                                             fabric.z, fabric.nz)
    return faces


_AXIS_OF = {"xp": 0, "xm": 0, "yp": 1, "ym": 1, "zp": 2, "zm": 2}
_SIGN_OF = {"xp": +1, "xm": -1, "yp": +1, "ym": -1, "zp": +1, "zm": -1}


def local_apply(
    coeffs: StencilCoeffs,
    v: jax.Array,
    fabric: FabricAxes,
    *,
    policy: Policy = F32,
    overlap: bool = True,
) -> jax.Array:
    """Local shard of u = A v with halo exchange.  Runs inside shard_map.

    ``overlap=False`` is the paper-faithful streaming form: each off-diagonal
    term consumes a full shifted copy built by concatenating the received
    face (the analogue of the CS-1 fabric streams feeding multiply threads).

    ``overlap=True`` is the TPU-native form: interior shifts (which are pure
    local compute) are accumulated first and each received face only patches
    one boundary plane — the collective-permutes have a minimal dependent
    region, so the scheduler can hide them under the interior work.
    """
    c = policy.compute
    faces = halo_faces(v, fabric)
    vc = v.astype(c)
    u = vc  # unit main diagonal (Jacobi preconditioning)

    for name, cf in coeffs.diags.items():
        ax, sign = _AXIS_OF[name], _SIGN_OF[name]
        cfc = cf.astype(c)
        if name in faces:
            face = faces[name].astype(c)
            if overlap:
                u = u + cfc * _shift(vc, ax, sign)
                # patch the single boundary plane that needed the halo
                sl = tuple(
                    (slice(-1, None) if sign > 0 else slice(0, 1)) if i == ax else slice(None)
                    for i in range(v.ndim)
                )
                u = u.at[sl].add(cfc[sl] * face)
            else:
                if sign > 0:
                    shifted = jnp.concatenate([_take_rest(vc, ax, 1), face], axis=ax)
                else:
                    shifted = jnp.concatenate([face, _take_rest(vc, ax, -1)], axis=ax)
                u = u + cfc * shifted
        else:
            # Z unsplit (single pod) or 2D: pure local shift, zero-Dirichlet.
            u = u + cfc * _shift(vc, ax, sign)
    return u.astype(policy.storage)


def _take_rest(v: jax.Array, axis: int, sign: int) -> jax.Array:
    sl = slice(1, None) if sign > 0 else slice(0, -1)
    return v[tuple(sl if i == axis else slice(None) for i in range(v.ndim))]


# ---------------------------------------------------------------------------
# Reductions (paper §IV-3: AllReduce for the BiCGStab inner products)
# ---------------------------------------------------------------------------

def fused_dots(pairs, axis_names, policy: Policy) -> jax.Array:
    """k inner products in ONE AllReduce (beyond-paper batching).

    Local FMAC-style partials (bf16 products, f32 accumulation — paper
    Table I's mixed column) are stacked into a length-k f32 vector and
    reduced with a single ``psum``, replacing k blocking AllReduces with one.
    """
    partials = jnp.stack([policy.dot(a, b) for a, b in pairs])
    return jax.lax.psum(partials, axis_names)


def separate_dots(pairs, axis_names, policy: Policy) -> jax.Array:
    """Paper-faithful: one blocking AllReduce per inner product."""
    return jnp.stack([jax.lax.psum(policy.dot(a, b), axis_names) for a, b in pairs])


def make_dots(fabric: FabricAxes, *, fused: bool = True):
    """Reduction callable ``dots(pairs, policy) -> f32[k]`` over the fabric."""
    names = tuple(a for a in (fabric.x, fabric.y, fabric.z) if a is not None)
    fn = fused_dots if fused else separate_dots
    return lambda pairs, policy: fn(pairs, names, policy)


def global_apply(mesh, coeffs: StencilCoeffs, v: jax.Array, *, policy: Policy = F32,
                 overlap: bool = True) -> jax.Array:
    """Convenience wrapper: one distributed SpMV on global arrays."""
    fabric = FabricAxes.from_mesh(mesh)
    spec = fabric.spec(v.ndim)

    def fn(cf, vv):
        return local_apply(cf, vv, fabric, policy=policy, overlap=overlap)

    return jax.shard_map(fn, mesh=mesh, in_specs=(spec, spec), out_specs=spec)(coeffs, v)
