"""Halo-exchange SpMV on the chip fabric (paper §IV-1, Figs. 3-5),
generalized to depth-r halos for the whole stencil family.

The paper's scheme: every core broadcasts its Z-pencil of the iterate to its
four fabric neighbors (one outgoing channel, four incoming channels — the
tessellation coloring of Fig. 5), multiplies the four received pencils with
the stored coefficient diagonals, and handles the two Z-shifted terms from a
local loopback.

TPU adaptation: a chip owns a ``(bx, by, Z)`` sub-volume, not a single
pencil, so only the *faces* of the block move.  The four neighbor channels
become four ``jax.lax.ppermute`` shifts (XLA ``collective-permute`` on the
ICI torus); fabric-edge chips receive zeros from ``ppermute``, which is
exactly the zero-Dirichlet boundary.  The CS-1 FIFO/task overlap machinery
is replaced by dataflow: the interior stencil terms do not depend on the
permutes, so XLA's latency-hiding scheduler runs the collectives under the
interior compute (``overlap=True`` makes this explicit by shrinking the
halo-dependent computation to the outer shell of the block).

Stencil-family generalization (:func:`gather_halo`): a radius-r spec moves
slabs of thickness r instead of single faces — the r stacked face shifts of
a depth-r exchange coalesced into one ``ppermute`` message per direction
per axis.  Star stencils exchange the axes independently (all collectives
overlappable); box stencils need edge/corner halo values, obtained by
exchanging the axes *sequentially* on the already-padded block so received
halos ride along to the diagonal neighbors (the standard corner-carrying
trick — no extra diagonal ppermutes on the torus).

All functions here are *local* (rank-per-shard) and must run inside
``jax.shard_map``; :mod:`repro.core.bicgstab` builds the global solver.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.precision import Policy, F32
from repro.core.stencil import StencilCoeffs, _shift_nd, name_offset


@dataclasses.dataclass(frozen=True)
class FabricAxes:
    """Names/sizes of the mesh axes carrying the stencil's X, Y (and Z) dims."""

    x: str = "data"
    nx: int = 1
    y: str = "model"
    ny: int = 1
    z: str | None = None          # pod axis slabs Z when multi-pod
    nz: int = 1

    @classmethod
    def from_mesh(cls, mesh) -> "FabricAxes":
        ax = dict(zip(mesh.axis_names, mesh.devices.shape))
        return cls(
            x="data", nx=ax["data"], y="model", ny=ax["model"],
            z="pod" if "pod" in ax else None, nz=ax.get("pod", 1),
        )

    def spec(self, ndim: int = 3, *, n_batch: int = 0) -> P:
        """PartitionSpec for a mesh-shaped field (X, Y[, Z]).

        ``n_batch`` prepends unsharded (replicated) axes for fields that
        carry a leading batch of right-hand sides: every shard owns its
        block of *all* B RHS, so the batch never moves over the fabric.
        """
        batch = (None,) * n_batch
        if ndim == 2:
            return P(*batch, self.x, self.y)
        return P(*batch, self.x, self.y, self.z)

    def split_info(self, ndim: int = 3) -> list[tuple[int, str | None, int]]:
        """(mesh axis, fabric axis name or None, fabric extent) per dimension."""
        info = [(0, self.x, self.nx), (1, self.y, self.ny)]
        if ndim == 3:
            info.append((2, self.z, self.nz))
        return info


def _exchange(face_lo, face_hi, axis_name: str, n: int):
    """Bidirectional nearest-neighbor exchange of two faces along one axis.

    Returns ``(from_lo, from_hi)``: the lower neighbor's high face and the
    upper neighbor's low face.  Edge shards receive zeros (Dirichlet).
    """
    if n == 1:
        return jnp.zeros_like(face_hi), jnp.zeros_like(face_lo)
    fwd = [(i, i + 1) for i in range(n - 1)]
    bwd = [(i + 1, i) for i in range(n - 1)]
    from_lo = jax.lax.ppermute(face_hi, axis_name, fwd)   # neighbor i-1 sent its high face
    from_hi = jax.lax.ppermute(face_lo, axis_name, bwd)   # neighbor i+1 sent its low face
    return from_lo, from_hi


def _take_slab(v: jax.Array, axis: int, sl: slice) -> jax.Array:
    return v[tuple(sl if i == axis else slice(None) for i in range(v.ndim))]


def gather_halo(
    v: jax.Array,
    fabric: FabricAxes,
    radius: int = 1,
    *,
    corners: bool = False,
    n_batch: int = 0,
) -> jax.Array:
    """The local block padded by ``radius`` on every axis, halos filled.

    This is the communication phase of the paper's SpMV, depth-r: each split
    axis exchanges a slab of thickness r (the r stacked face shifts of a
    depth-r halo coalesced into one ``ppermute`` message per direction).
    Unsplit axes and fabric edges are zero-padded — the global zero-Dirichlet
    boundary.

    ``n_batch`` leading axes of ``v`` are batch (many-RHS) axes: they are
    never padded or split, and each exchanged slab carries all B right-hand
    sides — a depth-r batched exchange moves ``(B, r, ...)`` slabs in the
    *same* number of ppermute messages as a single RHS, amortizing the
    per-message fabric latency across the whole batch.

    ``corners=False`` (star stencils): the axes exchange independently on the
    raw block, so all collectives are mutually independent and overlappable
    with interior compute; the edge/corner halo regions stay zero (a star
    never reads them).

    ``corners=True`` (box stencils): the axes exchange *sequentially* on the
    progressively padded block, so halo values received on earlier axes ride
    along to diagonal neighbors — edge/corner halos arrive without any extra
    diagonal messages on the torus.
    """
    r = radius
    nb = n_batch
    splits = [(ax + nb, name, n)
              for ax, name, n in fabric.split_info(v.ndim - nb)]
    for axis, name, n in splits:
        if name is not None and n > 1 and v.shape[axis] < r:
            raise ValueError(
                f"halo depth {r} exceeds the local block extent {v.shape[axis]} "
                f"on axis {axis}; use fewer shards or a larger mesh")

    if not corners:
        vp = jnp.pad(v, [(0, 0)] * nb + [(r, r)] * (v.ndim - nb))
        for axis, name, n in splits:
            if name is None or n == 1:
                continue
            lo = _take_slab(v, axis, slice(0, r))
            hi = _take_slab(v, axis, slice(v.shape[axis] - r, None))
            from_lo, from_hi = _exchange(lo, hi, name, n)
            idx = lambda sl: tuple(
                slice(None) if i < nb
                else sl if i == axis
                else slice(r, r + v.shape[i]) for i in range(v.ndim))
            vp = vp.at[idx(slice(0, r))].set(from_lo)
            vp = vp.at[idx(slice(r + v.shape[axis], None))].set(from_hi)
        return vp

    vp = v
    for axis, name, n in splits:
        if name is None or n == 1:
            pad = [(0, 0)] * vp.ndim
            pad[axis] = (r, r)
            vp = jnp.pad(vp, pad)
        else:
            m = vp.shape[axis]
            lo = _take_slab(vp, axis, slice(0, r))
            hi = _take_slab(vp, axis, slice(m - r, None))
            from_lo, from_hi = _exchange(lo, hi, name, n)
            vp = jnp.concatenate([from_lo, vp, from_hi], axis=axis)
    return vp


def _window(vp: jax.Array, off: tuple[int, ...], shape: tuple[int, ...],
            r: int, n_batch: int = 0) -> jax.Array:
    """The ``shape``-sized window of the r-padded block shifted by ``off``.

    ``n_batch`` leading axes of ``vp`` are unpadded batch axes, taken whole.
    """
    return vp[(slice(None),) * n_batch
              + tuple(slice(r + o, r + o + n) for o, n in zip(off, shape))]


def padded_apply(
    coeffs: StencilCoeffs,
    vp: jax.Array,
    shape: tuple[int, ...],
    *,
    policy: Policy = F32,
    region: tuple[slice, ...] | None = None,
) -> jax.Array:
    """u = A v from an r-padded local block (halos already in place).

    ``vp`` (and ``shape``) may carry a leading batch axis: the coefficients
    broadcast across it and ``region`` keeps addressing the trailing mesh
    dims only.

    ``region`` restricts the computation to a sub-box of the local block —
    used by the overlap schedule to recompute only the halo-dependent
    boundary ring (``core.comm.boundary_ring_apply``).
    """
    spec = coeffs.spec
    c = policy.compute
    nb = vp.ndim - coeffs.ndim
    mesh_shape = tuple(shape[len(shape) - coeffs.ndim:])
    reg = region if region is not None else tuple(slice(None) for _ in mesh_shape)
    vreg = (slice(None),) * nb + tuple(reg)
    sub = lambda off: _window(vp, off, mesh_shape, spec.radius, nb)[vreg].astype(c)
    center = sub((0,) * coeffs.ndim)
    if coeffs.diag is None:  # unit main diagonal (Jacobi-normalized family)
        u = center
    else:
        u = coeffs.diag[reg].astype(c) * center
    for name, cf in coeffs.ordered_items():   # canonical order — see StencilCoeffs
        u = u + cf[reg].astype(c) * sub(name_offset(name, coeffs.ndim))
    return u


def interior_apply(coeffs: StencilCoeffs, v: jax.Array, *,
                   policy: Policy = F32) -> jax.Array:
    """Zero-Dirichlet local apply in compute dtype — reads nothing a
    collective produced, so it is the work the overlap schedule runs while
    the halo faces are in flight.  Correct everywhere except the depth-r
    boundary ring bordering a split axis (patched afterwards).  ``v`` may
    carry a leading batch axis (shifts act on the trailing mesh dims)."""
    c = policy.compute
    nb = v.ndim - coeffs.ndim
    vc = v.astype(c)
    u = vc if coeffs.diag is None else coeffs.diag.astype(c) * vc
    for name, cf in coeffs.ordered_items():   # canonical order — see StencilCoeffs
        u = u + cf.astype(c) * _shift_nd(
            vc, (0,) * nb + name_offset(name, coeffs.ndim))
    return u


def local_apply(
    coeffs: StencilCoeffs,
    v: jax.Array,
    fabric: FabricAxes,
    *,
    policy: Policy = F32,
    overlap: bool | None = None,
    schedule=None,
) -> jax.Array:
    """Local shard of u = A v with depth-r halo exchange.  Runs inside
    shard_map and handles every spec in the stencil family (the halo depth,
    and whether corners are exchanged, derive from the coefficient names).

    The communication schedule is pluggable (``core.comm.SCHEDULES``):

    * ``blocking`` is the paper-faithful streaming form: every term reads
      the fully assembled halo'd block (the analogue of the CS-1 fabric
      streams feeding multiply threads).
    * ``overlap`` (default) issues the halo ``ppermute``s first, computes
      the interior while the faces are in flight, and patches only the
      depth-r boundary ring — bit-identical to blocking, with a minimal
      collective-dependent region for the latency-hiding scheduler.

    ``overlap=True/False`` is the legacy boolean spelling of the same
    choice; ``schedule`` (a name or :class:`~repro.core.comm.CommSchedule`)
    wins when both are given.
    """
    from repro.core.comm import get_schedule, scheduled_apply

    sched = get_schedule(schedule if schedule is not None else overlap)
    return scheduled_apply(coeffs, v, fabric, policy=policy, schedule=sched)


# Reductions (paper §IV-3: AllReduce for the BiCGStab inner products) live
# with the operator backends — ``core.operator._make_reductions`` builds the
# fused (one psum per sync point) / separate (one psum per dot) schedules;
# the pipelined solvers (core/solvers/pipelined.py) take the schedule down
# to one AllReduce per iteration.


def global_apply(mesh, coeffs: StencilCoeffs, v: jax.Array, *, policy: Policy = F32,
                 overlap: bool | None = None, schedule=None) -> jax.Array:
    """Convenience wrapper: one distributed SpMV on global arrays."""
    fabric = FabricAxes.from_mesh(mesh)
    nb = v.ndim - coeffs.ndim
    cf_spec = fabric.spec(coeffs.ndim)
    v_spec = fabric.spec(coeffs.ndim, n_batch=nb)

    def fn(cf, vv):
        return local_apply(cf, vv, fabric, policy=policy, overlap=overlap,
                           schedule=schedule)

    from repro.compat import shard_map
    return shard_map(fn, mesh=mesh, in_specs=(cf_spec, v_spec),
                     out_specs=v_spec, check_vma=False)(coeffs, v)
