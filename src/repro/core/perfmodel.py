"""Analytic performance model for the distributed BiCGStab iteration
(paper §V's model, re-derived for the TPU roofline).

The paper validates a simple model: iteration time = compute at the vector
unit rate + communication at the fabric rate, with the AllReduce adding a
diameter-bound latency.  On TPU the same three terms are:

  t_compute    = 44 flops/pt * pts_per_chip / peak
  t_memory     = words/pt * itemsize * pts_per_chip / HBM_bw
                 (words/pt = 42: 2 SpMV sweeps reading 6 diagonals + iterate
                  + writing result, 6 AXPY r/w sweeps, 4 dot reads — §IV's
                  10-vector working set traffic)
  t_collective = halo faces (4 or 6 per SpMV, 2 SpMV) / link_bw
                 + n_reductions * allreduce_latency(mesh)

and the iteration is bound by max(compute, memory) + collective (halos can
overlap interior compute; the blocking reductions cannot — the paper's
explicit design choice, §IV-3).
"""

from __future__ import annotations

import math

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HOP_LATENCY_S = 1e-6          # per-hop ICI latency (~us class)
FLOPS_PER_PT = 44.0
WORDS_PER_PT = 42.0


def allreduce_latency(px: int, py: int, pz: int = 1) -> float:
    """Latency-optimal AllReduce on a (px, py[, pz]) torus: ~2x diameter hops
    (reduce + broadcast), the paper's Fig. 6 scheme."""
    diameter = (px // 2) + (py // 2) + (pz // 2)
    return 2.0 * diameter * HOP_LATENCY_S


def iteration_time_model(mesh_shape, chips: int, *, itemsize: int = 2,
                         fused_reductions: bool = True,
                         fused_sweeps: bool = False,
                         pods: int = 1) -> dict:
    """Predicted BiCGStab iteration time for an X*Y*Z mesh on `chips` chips.

    ``fused_sweeps`` models the Pallas fused-iteration kernels (words/pt 42
    -> 28: SpMV+dot and AXPY+dot single passes, see kernels/fused_iter).
    """
    X, Y, Z = mesh_shape
    per_pod = chips // pods
    px = py = int(math.sqrt(per_pod))
    pts_chip = X * Y * Z / chips
    words = 28.0 if fused_sweeps else WORDS_PER_PT

    t_comp = FLOPS_PER_PT * pts_chip / PEAK_FLOPS
    t_mem = words * itemsize * pts_chip / HBM_BW

    # halos: 2 SpMVs x 4 faces of (block_y*Z or block_x*Z) + pod Z-faces
    bx, by = X / px, Y / py
    face_words = 2 * ((bx + by) * (Z / pods)) * 2  # both directions, per spmv
    if pods > 1:
        face_words += 2 * (bx * by) * 2
    t_halo = 2 * face_words * itemsize / LINK_BW
    n_red = 3 if fused_reductions else 5
    t_red = n_red * allreduce_latency(px, py, pods)

    # halos overlap interior compute (overlap=True path); only the fraction
    # the interior cannot hide is exposed
    t_interior = max(t_comp, t_mem)
    t_halo_exposed = max(0.0, t_halo - t_interior)
    t_iter = t_interior + t_red + t_halo_exposed
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_halo_s": t_halo,
        "t_reduce_s": t_red,
        "t_iter_s": t_iter,
        "bound": "memory" if t_mem >= t_comp else "compute",
    }


def mfix_timesteps_per_second(mesh_shape, chips: int, *,
                              simple_iters: int = 15,
                              mom_solver_iters: int = 5,
                              cont_solver_iters: int = 20) -> float:
    """Paper §VI-A projection: SIMPLE wall time from the iteration model +
    Table II's matrix-forming cost (~2 us per Z-meshpoint per timestep on
    CS-1; here scaled by the memory roofline of forming ~7-point systems)."""
    solve_iters = simple_iters * (3 * mom_solver_iters + cont_solver_iters)
    t_iter = iteration_time_model(mesh_shape, chips)["t_iter_s"]
    # forming: Table II total 165-364 cycles/pt -> ~60 memory words/pt
    X, Y, Z = mesh_shape
    t_form = simple_iters * 4 * 60 * 2 * (X * Y * Z / chips) / HBM_BW
    return 1.0 / (solve_iters * t_iter + t_form)
