"""Analytic performance model for the distributed BiCGStab iteration
(paper §V's model, re-derived for the TPU roofline).

The paper validates a simple model: iteration time = compute at the vector
unit rate + communication at the fabric rate, with the AllReduce adding a
diameter-bound latency.  On TPU the same three terms are:

  t_compute    = 44 flops/pt * pts_per_chip / peak
  t_memory     = words/pt * itemsize * pts_per_chip / HBM_bw
                 (words/pt = 42: 2 SpMV sweeps reading 6 diagonals + iterate
                  + writing result, 6 AXPY r/w sweeps, 4 dot reads — §IV's
                  10-vector working set traffic)
  t_collective = halo faces (4 or 6 per SpMV, 2 SpMV) / link_bw
                 + n_reductions * allreduce_latency(mesh)

and the iteration is bound by max(compute, memory) + collective (halos can
overlap interior compute under ``schedule="overlap"``; the blocking
reductions cannot — the paper's explicit design choice, §IV-3).

Communication-schedule extension: the model is parameterized over the
solver's collective structure (:data:`SOLVER_COMMS`) and the halo schedule
(``blocking`` exposes the full halo time; ``overlap`` only the fraction the
interior cannot hide).  The pipelined solvers trade 2 (CG) or 3 (BiCGStab)
reduction latencies per iteration for one, at the price of extra memory
sweeps — :func:`predict_crossover` locates the fabric size where that
trade wins, which ``benchmarks/allreduce_model.py`` and
``benchmarks/comm_overlap.py`` report against measured schedules.
"""

from __future__ import annotations

import dataclasses
import math

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HOP_LATENCY_S = 1e-6          # per-hop ICI latency (~us class)
FLOPS_PER_PT = 44.0
WORDS_PER_PT = 42.0


@dataclasses.dataclass(frozen=True)
class SolverComm:
    """Per-iteration communication/traffic structure of a registered solver.

    ``words_per_pt`` follows the §IV accounting style: SpMV sweeps read the
    coefficient diagonals + iterate and write the result (8 words each for
    star7), each AXPY-class update reads/writes 3 words, each dot reads 2.
    """

    n_spmv: int                  # SpMVs (= halo exchanges) per iteration
    reductions_fused: int        # AllReduces per iteration, fused schedule
    reductions_separate: int     # ... one psum per dot (paper-faithful)
    words_per_pt: float          # HBM words per meshpoint per iteration


#: solver name (core.solvers.SOLVERS) -> its collective structure.
SOLVER_COMMS = {
    # 2 SpMV (16) + 6 AXPY (18) + 4 dot reads (8) = 42 (§IV's 10-vector set)
    "bicgstab": SolverComm(2, 3, 5, 42.0),
    # 2 SpMV (16) + 9 AXPY (27) + 12 dot reads (24) = 67: the memory price
    # of the single-reduction reformulation (carried A-images z, t)
    "pipelined_bicgstab": SolverComm(2, 1, 12, 67.0),
    # 1 SpMV (8) + 3 AXPY (9) + 2 dot reads (4) = 21
    "cg": SolverComm(1, 2, 3, 21.0),
    # 1 SpMV (8) + 6 AXPY (18) + 2 dot reads (4) = 30 (Ghysels-Vanroose
    # z/s/p recurrence triple)
    "pipelined_cg": SolverComm(1, 1, 2, 30.0),
}


def allreduce_latency(px: int, py: int, pz: int = 1) -> float:
    """Latency-optimal AllReduce on a (px, py[, pz]) torus: ~2x diameter hops
    (reduce + broadcast), the paper's Fig. 6 scheme."""
    diameter = (px // 2) + (py // 2) + (pz // 2)
    return 2.0 * diameter * HOP_LATENCY_S


def iteration_time_model(mesh_shape, chips: int, *, itemsize: int = 2,
                         fused_reductions: bool = True,
                         fused_sweeps: bool = False,
                         solver: str = "bicgstab",
                         schedule: str = "overlap",
                         pods: int = 1) -> dict:
    """Predicted Krylov iteration time for an X*Y*Z mesh on `chips` chips.

    ``solver`` selects the per-iteration collective structure from
    :data:`SOLVER_COMMS`; ``schedule`` chooses whether the halo transfers
    hide under the interior apply (``overlap``) or serialize before it
    (``blocking``).  ``fused_sweeps`` models the Pallas fused-iteration
    kernels (BiCGStab words/pt 42 -> 28: SpMV+dot and AXPY+dot single
    passes, see kernels/fused_iter).
    """
    comm = SOLVER_COMMS[solver]
    X, Y, Z = mesh_shape
    per_pod = chips // pods
    px = py = int(math.sqrt(per_pod))
    pts_chip = X * Y * Z / chips
    words = comm.words_per_pt
    if fused_sweeps and solver == "bicgstab":
        words = 28.0

    t_comp = FLOPS_PER_PT * pts_chip / PEAK_FLOPS
    t_mem = words * itemsize * pts_chip / HBM_BW

    # halos: n_spmv x 4 faces of (block_y*Z or block_x*Z) + pod Z-faces
    bx, by = X / px, Y / py
    face_words = 2 * ((bx + by) * (Z / pods)) * 2  # both directions, per spmv
    if pods > 1:
        face_words += 2 * (bx * by) * 2
    t_halo = comm.n_spmv * face_words * itemsize / LINK_BW
    n_red = comm.reductions_fused if fused_reductions else comm.reductions_separate
    t_red = n_red * allreduce_latency(px, py, pods)

    t_interior = max(t_comp, t_mem)
    if schedule == "overlap":
        # halos hide under the interior apply; only the excess is exposed
        t_halo_exposed = max(0.0, t_halo - t_interior)
    elif schedule == "blocking":
        t_halo_exposed = t_halo
    else:
        raise KeyError(f"unknown schedule {schedule!r}; "
                       f"have ['blocking', 'overlap']")
    t_iter = t_interior + t_red + t_halo_exposed
    return {
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_halo_s": t_halo,
        "t_halo_exposed_s": t_halo_exposed,
        "t_reduce_s": t_red,
        "t_iter_s": t_iter,
        "n_reductions": n_red,
        "bound": "memory" if t_mem >= t_comp else "compute",
    }


def predict_crossover(mesh_shape, base: dict, alt: dict,
                      chip_counts=(4, 16, 64, 256, 1024, 4096, 16384, 65536),
                      **common) -> dict:
    """First fabric size where model config ``alt`` beats ``base``.

    ``base``/``alt`` are keyword overrides for :func:`iteration_time_model`
    (e.g. ``{"solver": "bicgstab"}`` vs ``{"solver": "pipelined_bicgstab"}``
    or ``{"schedule": "blocking"}`` vs ``{"schedule": "overlap"}``); the
    scan reports both predicted iteration times per chip count and the
    smallest count where the alternative is faster — the schedule-choice
    guidance ``benchmarks/comm_overlap.py`` publishes.
    """
    rows = []
    crossover = None
    for chips in chip_counts:
        t_base = iteration_time_model(mesh_shape, chips, **common, **base)
        t_alt = iteration_time_model(mesh_shape, chips, **common, **alt)
        rows.append({"chips": chips,
                     "t_base_s": t_base["t_iter_s"],
                     "t_alt_s": t_alt["t_iter_s"]})
        if crossover is None and t_alt["t_iter_s"] < t_base["t_iter_s"]:
            crossover = chips
    return {"base": base, "alt": alt, "mesh_shape": list(mesh_shape),
            "rows": rows, "crossover_chips": crossover}


def mfix_timesteps_per_second(mesh_shape, chips: int, *,
                              simple_iters: int = 15,
                              mom_solver_iters: int = 5,
                              cont_solver_iters: int = 20) -> float:
    """Paper §VI-A projection: SIMPLE wall time from the iteration model +
    Table II's matrix-forming cost (~2 us per Z-meshpoint per timestep on
    CS-1; here scaled by the memory roofline of forming ~7-point systems)."""
    solve_iters = simple_iters * (3 * mom_solver_iters + cont_solver_iters)
    t_iter = iteration_time_model(mesh_shape, chips)["t_iter_s"]
    # forming: Table II total 165-364 cycles/pt -> ~60 memory words/pt
    X, Y, Z = mesh_shape
    t_form = simple_iters * 4 * 60 * 2 * (X * Y * Z / chips) / HBM_BW
    return 1.0 / (solve_iters * t_iter + t_form)
