"""Preconditioners (beyond-paper: the iteration-count lever the WSE
follow-on work identifies — Woo et al., Jacquelin et al.).

Two families, both *local* operations so the per-iteration collective
schedule of the solve is unchanged (the whole point of right
preconditioning on this fabric):

* :class:`JacobiPrecond` — ``M^-1 = D^-1`` from the stencil's stored main
  diagonal.  The paper's operators are pre-normalized (unit diagonal — the
  paper itself applies Jacobi by construction, "we only store six other
  diagonals"), so Jacobi is the identity for them; it does real work for
  *raw* operators that carry a variable diagonal
  (``stencil.heterogeneous_poisson``).  Zero setup, zero extra SpMVs.

* :class:`ChebyshevPrecond` — a degree-d Chebyshev polynomial approximation
  of ``A^-1`` on a spectral interval ``[lmin, lmax]`` (the classic
  Chebyshev semi-iteration with zero initial guess, the hypre/AMG smoother
  recurrence).  Costs d-1 extra SpMVs per application — local halo
  exchanges only, **no extra AllReduces** — and repays them by clustering
  the spectrum, cutting the outer (AllReduce-bearing) iteration count.
  Bounds default to fabric-reduced Gershgorin estimates with a relative
  floor on ``lmin``.

Preconditioners are built *inside* the shard_map body (they close over
local coefficient shards and the operator's local apply); the static
choices (name, degree, floor, explicit bounds) travel in a
:class:`PrecondConfig` resolved by the driver.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.core.operator import LinearOperator
from repro.core.solvers.common import SolveResult


@dataclasses.dataclass(frozen=True)
class PrecondConfig:
    """Static (trace-time) preconditioner choices.

    ``lmin``/``lmax`` override the Gershgorin estimate when given;
    ``lmin_floor`` keeps the Chebyshev interval away from a zero Gershgorin
    lower bound (the weakly-dominant Poisson case) — eigenvalues below the
    floor are left to the outer Krylov solver as isolated outliers.
    """

    name: str = "none"
    degree: int = 3
    lmin: float | None = None
    lmax: float | None = None
    lmin_floor: float = 0.05

    def __post_init__(self):
        if self.name not in PRECONDS:
            raise ValueError(f"unknown preconditioner {self.name!r}; "
                             f"have {sorted(PRECONDS)}")
        if self.degree < 1:
            raise ValueError(f"chebyshev degree must be >= 1, got {self.degree}")


def get_precond_config(name_or_config, **overrides) -> PrecondConfig:
    """Normalize a CLI string / None / config into a PrecondConfig."""
    if name_or_config is None:
        name_or_config = "none"
    if isinstance(name_or_config, PrecondConfig):
        return (dataclasses.replace(name_or_config, **overrides)
                if overrides else name_or_config)
    return PrecondConfig(name=name_or_config, **overrides)


# ---------------------------------------------------------------------------
# The preconditioners
# ---------------------------------------------------------------------------

class IdentityPrecond:
    name = "none"

    def apply(self, v):
        return v


@dataclasses.dataclass(frozen=True)
class JacobiPrecond:
    """Right diagonal scaling: ``M^-1 v = v / diag``."""

    inv_diag: jnp.ndarray
    storage: jnp.dtype
    compute: jnp.dtype
    name: str = "jacobi"

    def apply(self, v):
        return (v.astype(self.compute)
                * self.inv_diag.astype(self.compute)).astype(self.storage)

    def apply_inv(self, v):
        """``M v`` — exact inverse of :meth:`apply`, used to translate warm
        starts into hat space (see :func:`warm_start`)."""
        return (v.astype(self.compute)
                / self.inv_diag.astype(self.compute)).astype(self.storage)


@dataclasses.dataclass(frozen=True)
class ChebyshevPrecond:
    """``M^-1 v ~= A^-1 v`` via the degree-d Chebyshev semi-iteration.

    Standard three-term recurrence for solving ``A z = v`` from ``z0 = 0``
    with the spectrum enclosed in ``[lmin, lmax]`` (d=1 degenerates to
    ``v / theta``, the scaled-identity smoother).  All work is SpMVs and
    AXPYs — halo exchanges, no reductions.
    """

    apply_A: Callable
    degree: int
    lmin: jnp.ndarray
    lmax: jnp.ndarray
    storage: jnp.dtype
    compute: jnp.dtype
    name: str = "chebyshev"

    def apply(self, v):
        c, st = self.compute, self.storage
        theta = jnp.float32((self.lmax + self.lmin) / 2)
        delta = jnp.float32((self.lmax - self.lmin) / 2)
        sigma1 = theta / delta
        rho = 1.0 / sigma1
        r = v.astype(c)
        d = r * (1.0 / theta).astype(c)
        z = d
        for _ in range(1, self.degree):
            r = r - self.apply_A(d.astype(st)).astype(c)
            rho_new = 1.0 / (2.0 * sigma1 - rho)
            d = (rho_new * rho).astype(c) * d + (2.0 * rho_new / delta).astype(c) * r
            z = z + d
            rho = rho_new
        return z.astype(st)


PRECONDS = ("none", "jacobi", "chebyshev")


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def gershgorin_bounds(coeffs):
    """Local Gershgorin disc bounds (min over rows of d - R, max of d + R).

    Traceable (pure jnp) so the distributed path can reduce the local
    extrema over the fabric with the operator's ``reduce_max``.
    """
    s = None
    for cf in coeffs.diags.values():
        a = jnp.abs(cf.astype(jnp.float32))
        s = a if s is None else s + a
    d = (coeffs.diag.astype(jnp.float32) if coeffs.diag is not None
         else jnp.ones_like(s))
    return jnp.min(d - s), jnp.max(d + s)


def build_precond(config: PrecondConfig, op: LinearOperator):
    """Instantiate a preconditioner against an operator (inside shard_map)."""
    if config.name == "none":
        return IdentityPrecond()
    pol = op.policy
    if config.name == "jacobi":
        if op.coeffs.diag is None:
            return IdentityPrecond()  # the family is already unit-diagonal
        return JacobiPrecond(inv_diag=1.0 / op.coeffs.diag.astype(jnp.float32),
                             storage=pol.storage, compute=pol.compute)
    # chebyshev
    if config.lmin is not None and config.lmax is not None:
        lmin = jnp.float32(config.lmin)
        lmax = jnp.float32(config.lmax)
    else:
        lo, hi = gershgorin_bounds(op.coeffs)
        lmax = op.reduce_max(hi) if config.lmax is None else jnp.float32(config.lmax)
        if config.lmin is None:
            lmin = -op.reduce_max(-lo)
            lmin = jnp.maximum(lmin, config.lmin_floor * lmax)
        else:
            lmin = jnp.float32(config.lmin)
    return ChebyshevPrecond(apply_A=op.apply, degree=config.degree,
                            lmin=lmin, lmax=lmax,
                            storage=pol.storage, compute=pol.compute)


def warm_start(precond, x0):
    """Translate a real-space warm start into hat space: ``x0_hat = M x0``.

    Solvers hand ``x0`` to the hat system ``A M^-1``, whose iterate is
    ``x_hat = M x``; a preconditioner with an exact ``apply_inv`` therefore
    maps the guess so the initial residual is ``b - A x0``, exactly the
    unpreconditioned start (truncated inner solves — e.g. SIMPLE's 5-iter
    momentum solves — rely on this, or every solve restarts from ``M^-1
    x0`` instead of ``x0``).  Preconditioners without an inverse (Chebyshev)
    use the guess as-is: any hat-space start is valid, just not warm.
    """
    if x0 is None or precond is None:
        return x0
    apply_inv = getattr(precond, "apply_inv", None)
    return x0 if apply_inv is None else apply_inv(x0)


def wrap_right(op: LinearOperator, precond):
    """Right-precondition an operator: returns ``(wrapped_op, unwrap)``.

    ``wrapped_op.apply(v) = A(M^-1 v)`` (the hat system — residuals,
    convergence test and collective schedule are identical to the
    unpreconditioned solve); ``unwrap`` maps a hat-space SolveResult back,
    ``x = M^-1 x_hat``.  A warm start ``x0`` is interpreted in hat space;
    solvers translate real-space guesses with :func:`warm_start`.
    """
    if precond is None or isinstance(precond, IdentityPrecond):
        return op, lambda res: res

    wrapped = op.with_apply(lambda v: op.apply(precond.apply(v)))

    def unwrap(res: SolveResult) -> SolveResult:
        return dataclasses.replace(res, x=precond.apply(res.x))

    return wrapped, unwrap
