"""The LinearOperator layer: one protocol, three interchangeable backends.

A :class:`LinearOperator` bundles everything a Krylov solver needs from the
matrix side —

* ``apply(v)``            : u = A v (the SpMV, local to this shard);
* ``dots(pairs, policy)`` : fully-reduced inner products (the operator owns
  the reduction schedule: local stack / fused psum / separate psums);
* ``reduce_partials(ps)`` : AllReduce of *precomputed* f32 local partials
  (the fused-kernel path computes partials inside Pallas epilogues and only
  needs the reduction);
* ``reduce_max(x)``       : fabric-wide max (spectral-bound setup);
* ``fused``               : optional :class:`FusedOps` — the Pallas fused
  update+dot passes that let BiCGStab run one iteration as fused kernels
  plus exactly 3 AllReduces.

Backends (:data:`BACKENDS`):

* ``reference`` — dense-shift oracle in a single address space (tests,
  small examples, the truth everything else is checked against);
* ``spmd``      — depth-r halo-exchange ``local_apply`` + psum reductions;
  must run inside ``shard_map`` (construct it in the mapped function over
  the *local* coefficient shard);
* ``pallas``    — the halo exchange feeding the fused stencil kernel
  (``kernels/stencil_nd``) plus the ``kernels/fused_iter`` vector passes,
  wired into the same shard_map loop.

Operators are built *inside* the shard_map body (they close over local
shards); drivers in ``core/bicgstab.py`` do that wiring.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.comm import CommSchedule, OVERLAP, get_schedule, scheduled_apply
from repro.core.halo import FabricAxes
from repro.core.precision import Policy, F32
from repro.core.solvers.common import local_dots, local_partial
from repro.core.stencil import StencilCoeffs, apply_ref


@dataclasses.dataclass(frozen=True)
class FusedOps:
    """The fused Pallas iteration passes (see ``kernels/fused_iter``).

    Each callable returns its vector output(s) plus f32 *local* partial dot
    products; the solver batches the partials of one sync point into a
    single ``reduce_partials`` AllReduce.
    """

    dot_partial: Callable      # (a, b) -> f32 partial <a, b>
    update_q_dots: Callable    # (alpha, r, s, y) -> (q, <q,y>, <y,y>)
    update_xr_dots: Callable   # (alpha, omega, x, p, q, y, r0) -> (x, r, <r0,r>, <r,r>)
    update_p: Callable         # (beta, omega, r, p, s) -> p


@dataclasses.dataclass(frozen=True)
class LinearOperator:
    """A shard-local view of ``A`` plus its communication schedule.

    ``schedule`` is the halo-side :class:`~repro.core.comm.CommSchedule`
    the ``apply`` was built with (blocking vs overlapped exchange); the
    reduction side lives in ``dots``/``reduce_partials`` (fused vs separate
    psums) and, one level up, in the solver's recurrence structure (the
    pipelined variants fuse every sync point into one AllReduce).
    """

    name: str
    coeffs: StencilCoeffs
    policy: Policy
    apply: Callable
    dots: Callable
    reduce_partials: Callable
    reduce_max: Callable
    fused: FusedOps | None = None
    schedule: CommSchedule = OVERLAP

    @property
    def spec(self):
        return self.coeffs.spec

    def with_apply(self, apply: Callable) -> "LinearOperator":
        """A copy with the SpMV swapped (how right preconditioning wraps)."""
        return dataclasses.replace(self, apply=apply)


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

def _identity_reduce(partials):
    return jnp.stack([jnp.asarray(p, jnp.float32) for p in partials])


def _fabric_axis_names(fabric: FabricAxes) -> tuple[str, ...]:
    """Mesh axes that actually carry >1 shard.  Extent-1 axes reduce to the
    identity, and skipping them lets the distributed backends also run
    outside shard_map on a degenerate 1x1 fabric (single-block fused path).
    """
    pairs = ((fabric.x, fabric.nx), (fabric.y, fabric.ny), (fabric.z, fabric.nz))
    return tuple(a for a, n in pairs if a is not None and n > 1)


def _make_reductions(names: tuple[str, ...], fused_reductions: bool,
                     mesh_ndim: int | None = None):
    """(dots, reduce_partials, reduce_max) over the named fabric axes.

    ``mesh_ndim`` enables the batched (many-RHS) path: operands of higher
    rank produce per-RHS ``[B]`` partials, and a fused sync point psums the
    stacked ``[k, B]`` array in ONE AllReduce — the collective count is
    independent of the batch size.
    """
    def psum(x):
        return jax.lax.psum(x, names) if names else x

    if fused_reductions:
        def reduce_partials(ps):
            return psum(_identity_reduce(ps))
    else:
        def reduce_partials(ps):
            return jnp.stack([psum(jnp.asarray(p, jnp.float32)) for p in ps])

    def dots(pairs, policy):
        # local FMAC-style partials (see Policy.dot; per-RHS rows when
        # batched), then one psum per sync point (fused) or per dot
        # (paper-faithful separate)
        return reduce_partials(
            [local_partial(a, b, policy, mesh_ndim=mesh_ndim)
             for a, b in pairs])

    def reduce_max(x):
        return jax.lax.pmax(x, names) if names else x

    return dots, reduce_partials, reduce_max


def reference_operator(coeffs: StencilCoeffs, *, policy: Policy = F32,
                       schedule=None, **_unused) -> LinearOperator:
    """Single-address-space oracle: dense-shift apply, local reductions.

    There is no communication to schedule; ``schedule`` is validated and
    recorded so driver plumbing treats every backend uniformly.
    """
    cf = coeffs.astype(policy.storage)
    return LinearOperator(
        name="reference", coeffs=cf, policy=policy,
        apply=lambda v: apply_ref(cf, v, policy=policy),
        dots=lambda pairs, policy: local_dots(pairs, policy,
                                              mesh_ndim=cf.ndim),
        reduce_partials=_identity_reduce,
        reduce_max=lambda x: x,
        schedule=get_schedule(schedule),
    )


def spmd_operator(coeffs: StencilCoeffs, fabric: FabricAxes | None = None, *,
                  policy: Policy = F32, overlap: bool | None = None,
                  schedule=None, fused_reductions: bool = True,
                  **_unused) -> LinearOperator:
    """Halo-exchange SPMD backend (the paper's scheme; runs inside shard_map).

    ``schedule`` picks the halo schedule (``core.comm.SCHEDULES``); the
    legacy ``overlap`` boolean spells the same choice and loses ties.
    """
    fabric = fabric or FabricAxes()
    cf = coeffs.astype(policy.storage)
    sched = get_schedule(schedule if schedule is not None else overlap)
    dots, reduce_partials, reduce_max = _make_reductions(
        _fabric_axis_names(fabric), fused_reductions, mesh_ndim=cf.ndim)
    return LinearOperator(
        name="spmd", coeffs=cf, policy=policy,
        apply=lambda v: scheduled_apply(cf, v, fabric, policy=policy,
                                        schedule=sched),
        dots=dots,
        reduce_partials=reduce_partials,
        reduce_max=reduce_max,
        schedule=sched,
    )


def pallas_operator(coeffs: StencilCoeffs, fabric: FabricAxes | None = None, *,
                    policy: Policy = F32, overlap: bool | None = None,
                    schedule=None, fused_reductions: bool = True,
                    interpret: bool | None = None,
                    fuse_ring: bool | None = None, **_unused) -> LinearOperator:
    """Pallas-fused backend: halo exchange + fused stencil kernel for the
    SpMV, ``kernels/fused_iter`` passes for the vector updates and dot
    partials.  Runs inside shard_map; one BiCGStab iteration lowers to
    fused kernels + 3 AllReduces end to end.

    Kernel tile shapes resolve through the persistent tuning cache
    (``core/tuning``) at trace time, so a swept {spec x dtype x local
    shape} cell transparently gets its tuned config.  ``fuse_ring``
    overrides the cache's boundary-ring epilogue choice for the overlap
    schedule (None = let the cache decide).
    """
    from repro.compat import resolve_interpret
    from repro.kernels.fused_iter import (
        dot_mixed, update_p, update_q_dots, update_xr_dots,
    )
    from repro.kernels.stencil_nd.ops import pallas_local_apply

    fabric = fabric or FabricAxes()
    cf = coeffs.astype(policy.storage)
    sched = get_schedule(schedule if schedule is not None else overlap)
    it = resolve_interpret(interpret)
    _dots, reduce_partials, reduce_max = _make_reductions(
        _fabric_axis_names(fabric), fused_reductions, mesh_ndim=cf.ndim)

    cf_unit = StencilCoeffs(cf.diags)  # the kernel's unit-diagonal contract
    base_apply = lambda v: pallas_local_apply(cf_unit, v, fabric, policy=policy,
                                              schedule=sched, interpret=it,
                                              fuse_ring=fuse_ring)
    if cf.diag is None:
        apply = base_apply
    else:
        # The stencil kernel assumes the family's unit main diagonal; a raw
        # (non-normalized) operator adds its (d - 1) deviation elementwise.
        c = policy.compute
        dcorr = (cf.diag.astype(c) - jnp.asarray(1, c))

        def apply(v):
            return (base_apply(v).astype(c) + dcorr * v.astype(c)).astype(policy.storage)

    # the fused_iter passes switch to their per-RHS-tiled variants whenever
    # an operand carries a leading batch axis (rank above the mesh rank)
    batched = lambda a: a.ndim > cf.ndim
    dot_partial = lambda a, b: dot_mixed(a, b, interpret=it,
                                         batched=batched(a))

    return LinearOperator(
        name="pallas", coeffs=cf, policy=policy,
        apply=apply,
        dots=lambda pairs, policy: reduce_partials(
            [dot_partial(a, b) for a, b in pairs]),
        reduce_partials=reduce_partials,
        reduce_max=reduce_max,
        schedule=sched,
        fused=FusedOps(
            dot_partial=dot_partial,
            update_q_dots=lambda alpha, r, s, y: update_q_dots(
                alpha, r, s, y, interpret=it, batched=batched(r)),
            update_xr_dots=lambda alpha, omega, x, p, q, y, r0: update_xr_dots(
                alpha, omega, x, p, q, y, r0, interpret=it, batched=batched(x)),
            update_p=lambda beta, omega, r, p, s: update_p(
                beta, omega, r, p, s, interpret=it, batched=batched(r)),
        ),
    )


#: backend name -> constructor; launch/solve.py and benchmarks key off this.
BACKENDS = {
    "reference": reference_operator,
    "spmd": spmd_operator,
    "pallas": pallas_operator,
}


def make_operator(backend: str, coeffs: StencilCoeffs,
                  fabric: FabricAxes | None = None, *, policy: Policy = F32,
                  **kwargs) -> LinearOperator:
    """Build a backend by name.  ``fabric`` is required semantics for the
    distributed backends (pass the shard_map-local view); the reference
    backend ignores it."""
    try:
        ctor = BACKENDS[backend]
    except KeyError:
        raise KeyError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}") from None
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    obs_metrics.counter(f"operator.build.{backend}").inc()
    with obs_trace.span("operator.build", backend=backend,
                        stencil=coeffs.spec.name, policy=policy.name):
        if backend == "reference":
            return ctor(coeffs, policy=policy, **kwargs)
        return ctor(coeffs, fabric, policy=policy, **kwargs)
