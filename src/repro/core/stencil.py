"""Stencil-family operators (paper §IV, generalized beyond the 7-point shape).

The paper's matrix ``A`` has seven nonzero diagonals; after diagonal (Jacobi)
preconditioning the main diagonal is all ones, so only the six off-diagonals
are stored (paper: "we only store six other diagonals").  Coefficients are
stored as one mesh-shaped array per diagonal, exactly the per-core layout of
Listing 1 (xp, xm, yp, ym, zp, zm) generalized from one Z-pencil per core to
one sub-volume per chip.

This module generalizes that layout to a stencil *family* parameterized by a
:class:`StencilSpec` — pattern ∈ {star, box} and radius r:

* ``star`` r=1 is the paper's 7-point shape (5-point in 2D);
* ``star`` r=4 is the 25-point shape of Jacquelin et al.'s seismic-RTM
  stencil (8th-order finite differences, 8 points per axis + center);
* ``box``  r=1 is the 27-point shape (corner/edge couplings, e.g. trilinear
  FEM mass matrices and Belli & De Sensi's WSE stencil study).

Each off-diagonal is named canonically (legacy ``xp``/``zm`` names for the
radius-1 star offsets, ``xp2``-style names for deeper star offsets,
``d1_-1_0``-style names for box offsets) so a :class:`StencilCoeffs` is
self-describing: :func:`spec_of` recovers the spec from the diagonal names.

Boundary semantics are zero-Dirichlet: a shift that crosses the mesh edge
contributes zero (on CS-1 this was achieved by zero-padding the local
arrays; here by zero-fill of ``ppermute`` at fabric edges / ``jnp.pad``).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import Policy, F32

# Order matters and is shared with the Pallas kernel and the dense builder.
DIAGS_3D = ("xp", "xm", "yp", "ym", "zp", "zm")
DIAGS_2D = ("xp", "xm", "yp", "ym")

# Offset (in mesh coordinates) of the neighbor each diagonal reads.
OFFSETS = {
    "xp": (1, 0, 0), "xm": (-1, 0, 0),
    "yp": (0, 1, 0), "ym": (0, -1, 0),
    "zp": (0, 0, 1), "zm": (0, 0, -1),
}

_AXES = "xyz"
_STAR_NAME = re.compile(r"^([xyz])([pm])(\d*)$")


def offset_name(off: tuple[int, ...]) -> str:
    """Canonical diagonal name of a neighbor offset.

    Radius-1 star offsets keep the paper's names (``xp`` .. ``zm``); deeper
    star offsets append the distance (``xp2`` reads ``v[i+2,j,k]``); offsets
    touching more than one axis (box stencils) spell the vector out
    (``d1_-1_0`` reads ``v[i+1,j-1,k]``).
    """
    nz = [(i, o) for i, o in enumerate(off) if o != 0]
    if len(nz) == 1:
        ax, o = nz[0]
        base = f"{_AXES[ax]}{'p' if o > 0 else 'm'}"
        return base if abs(o) == 1 else f"{base}{abs(o)}"
    return "d" + "_".join(str(o) for o in off)


def name_offset(name: str, ndim: int = 3) -> tuple[int, ...]:
    """Inverse of :func:`offset_name` (also accepts the legacy names)."""
    if name.startswith("d"):
        off = tuple(int(t) for t in name[1:].split("_"))
        if len(off) != ndim:
            raise ValueError(f"offset name {name!r} is {len(off)}-D, mesh is {ndim}-D")
        return off
    m = _STAR_NAME.match(name)
    if not m:
        raise ValueError(f"unrecognized diagonal name {name!r}")
    ax = _AXES.index(m.group(1))
    dist = int(m.group(3) or 1) * (1 if m.group(2) == "p" else -1)
    if ax >= ndim:
        raise ValueError(f"diagonal {name!r} names axis {ax} on a {ndim}-D mesh")
    return tuple(dist if i == ax else 0 for i in range(ndim))


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A stencil shape: ``star`` (axis-aligned arms) or ``box`` (full cube).

    ``star`` with radius r couples ``2*ndim*r`` neighbors (r=1 => the paper's
    7-point shape); ``box`` couples ``(2r+1)**ndim - 1`` (r=1 => 27-point).
    The spec carries no coefficients — it is the *shape* contract shared by
    the reference apply, the halo exchange (depth = radius, corners only for
    box), and the fused Pallas kernel.
    """

    pattern: str            # "star" | "box"
    radius: int
    ndim: int = 3

    def __post_init__(self):
        if self.pattern not in ("star", "box"):
            raise ValueError(f"pattern must be 'star' or 'box', got {self.pattern!r}")
        if self.radius < 1:
            raise ValueError(f"radius must be >= 1, got {self.radius}")
        if self.ndim not in (2, 3):
            raise ValueError(f"ndim must be 2 or 3, got {self.ndim}")

    @functools.cached_property
    def offsets(self) -> tuple[tuple[int, ...], ...]:
        """Neighbor offsets (center excluded), in canonical order.

        Star order extends the legacy (xp, xm, yp, ym, zp, zm): axis-major,
        then distance, ``+`` before ``-`` — so radius-1 star names/order are
        bit-identical with the seed's 7-point layout.
        """
        if self.pattern == "star":
            offs = []
            for ax in range(self.ndim):
                for dist in range(1, self.radius + 1):
                    for sign in (+1, -1):
                        offs.append(tuple(sign * dist if i == ax else 0
                                          for i in range(self.ndim)))
            return tuple(offs)
        rng = range(-self.radius, self.radius + 1)
        return tuple(o for o in itertools.product(*([rng] * self.ndim))
                     if any(o))

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(offset_name(o) for o in self.offsets)

    @property
    def n_offsets(self) -> int:
        return len(self.offsets)

    @property
    def n_points(self) -> int:
        """Stencil points including the center (7, 13, 25, 27, ...)."""
        return self.n_offsets + 1

    @property
    def name(self) -> str:
        return f"{self.pattern}{self.n_points}"

    @property
    def needs_corners(self) -> bool:
        """True iff the halo exchange must fill edge/corner halo regions."""
        return self.pattern == "box"


STAR7 = StencilSpec("star", 1, 3)
STAR13 = StencilSpec("star", 2, 3)
STAR25 = StencilSpec("star", 4, 3)
BOX27 = StencilSpec("box", 1, 3)

#: CLI-facing registry; launch/solve.py, configs and benchmarks key off this.
SPECS = {s.name: s for s in (STAR7, STAR13, STAR25, BOX27)}


def get_spec(name: str) -> StencilSpec:
    try:
        return SPECS[name]
    except KeyError:
        raise KeyError(f"unknown stencil {name!r}; have {sorted(SPECS)}") from None


def spec_of(names, ndim: int = 3) -> StencilSpec:
    """Recover the spec a set of diagonal names was generated from.

    Pattern is ``box`` iff any offset touches more than one axis; radius is
    the max offset magnitude.  Used by the halo exchange and the kernels to
    size the halo without threading a spec argument through every call.
    """
    offs = [name_offset(n, ndim) for n in names]
    radius = max(max(abs(o) for o in off) for off in offs)
    box = any(sum(o != 0 for o in off) > 1 for off in offs)
    return StencilSpec("box" if box else "star", radius, ndim)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StencilCoeffs:
    """Off-diagonal coefficient fields of a stencil matrix.

    ``diags[name]`` has the mesh shape; entry ``diags['xp'][i,j,k]`` multiplies
    ``v[i+1,j,k]`` when computing row ``(i,j,k)`` of ``A @ v``.

    ``diag`` is the main diagonal: ``None`` means the family's canonical
    *unit* diagonal (the paper's Jacobi-normalized form — "we only store six
    other diagonals"); a stored array makes this a *raw* operator whose
    diagonal varies per row (e.g. :func:`heterogeneous_poisson`), the case
    where Jacobi preconditioning does real work.
    """

    diags: dict[str, jax.Array]
    diag: jax.Array | None = None

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self.diags)

    @property
    def shape(self) -> tuple[int, ...]:
        return next(iter(self.diags.values())).shape

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        return next(iter(self.diags.values())).dtype

    @property
    def spec(self) -> StencilSpec:
        """The :class:`StencilSpec` implied by the diagonal names."""
        return spec_of(self.names, self.ndim)

    def offsets(self) -> dict[str, tuple[int, ...]]:
        """name -> neighbor offset for every stored diagonal."""
        return {n: name_offset(n, self.ndim) for n in self.diags}

    def ordered_items(self) -> list[tuple[str, jax.Array]]:
        """(name, coefficient) pairs in the spec's canonical offset order.

        Pytree boundaries re-sort the ``diags`` dict, so its iteration
        order is not stable.  Every apply path (``apply_ref``, the halo
        interior/padded applies, the Pallas kernel's argument order)
        accumulates terms in THIS order — the single invariant behind the
        cross-schedule bitwise-identity guarantee of ``core/comm.py``.
        """
        return [(n, self.diags[n]) for n in self.spec.names if n in self.diags]

    def astype(self, dtype) -> "StencilCoeffs":
        return StencilCoeffs(
            {k: v.astype(dtype) for k, v in self.diags.items()},
            diag=None if self.diag is None else self.diag.astype(dtype))

    def normalized(self) -> tuple["StencilCoeffs", jax.Array | None]:
        """Left-Jacobi row scaling: ``(unit-diagonal coeffs, diag)``.

        ``D^-1 A`` has unit diagonal and off-diagonals ``cf/diag`` — exactly
        the paper's pre-normalization.  Returns ``(self, None)`` when
        already normalized.
        """
        if self.diag is None:
            return self, None
        d = self.diag
        return StencilCoeffs({k: v / d.astype(v.dtype)
                              for k, v in self.diags.items()}), d

    def tree_flatten(self):
        keys = tuple(sorted(self.diags))
        children = tuple(self.diags[k] for k in keys)
        if self.diag is not None:
            return children + (self.diag,), (keys, True)
        return children, (keys, False)

    @classmethod
    def tree_unflatten(cls, aux, values):
        # pre-diag pickles/callers may pass bare key tuples
        if len(aux) == 2 and isinstance(aux[1], bool):
            keys, has_diag = aux
        else:
            keys, has_diag = aux, False
        if has_diag:
            return cls(dict(zip(keys, values[:-1])), diag=values[-1])
        return cls(dict(zip(keys, values)))


def _shift(v: jax.Array, axis: int, offset: int) -> jax.Array:
    """v shifted so result[i] = v[i + offset] along ``axis``; zero fill."""
    if offset == 0:
        return v
    pad = [(0, 0)] * v.ndim
    if offset > 0:
        pad[axis] = (0, offset)
        return jnp.pad(v, pad)[
            tuple(slice(offset, None) if a == axis else slice(None) for a in range(v.ndim))
        ]
    pad[axis] = (-offset, 0)
    return jnp.pad(v, pad)[
        tuple(slice(0, offset) if a == axis else slice(None) for a in range(v.ndim))
    ]


def _shift_nd(v: jax.Array, off: tuple[int, ...]) -> jax.Array:
    """v shifted by a (possibly multi-axis) offset, zero fill at the edges."""
    for axis, o in enumerate(off):
        if o != 0:
            v = _shift(v, axis, o)
    return v


def apply_ref(coeffs: StencilCoeffs, v: jax.Array, *, policy: Policy = F32) -> jax.Array:
    """Reference (single-address-space) u = A v.  Oracle for everything else.

    Works for every stencil in the family: each stored diagonal contributes
    ``coeff * v[idx + offset]`` with zero-Dirichlet shifts.  Follows the
    paper's arithmetic: products and accumulating adds run in
    ``policy.compute`` (Table I counts these as half precision in the mixed
    policy); the unit diagonal contributes ``v`` directly.

    ``v`` may carry a leading batch axis (shape ``(B,) + coeffs.shape``):
    the offsets act on the trailing mesh dims and the coefficients
    broadcast across the batch, so one call applies A to B right-hand
    sides at once (and the ``B=1`` result is bitwise identical to the
    unbatched apply — same elementwise arithmetic, broadcast axis aside).

    Terms accumulate in the canonical order of ``coeffs.ordered_items()``
    — the same order every distributed apply path and the Pallas kernel
    use, which keeps the backends bit-comparable.
    """
    c = policy.compute
    nb = v.ndim - coeffs.ndim          # leading batch axes (0 or 1)
    if coeffs.diag is None:
        u = v.astype(c)
    else:
        u = coeffs.diag.astype(c) * v.astype(c)
    for name, cf in coeffs.ordered_items():
        off = (0,) * nb + name_offset(name, coeffs.ndim)
        u = u + cf.astype(c) * _shift_nd(v, off).astype(c)
    return u.astype(policy.storage)


def to_dense(coeffs: StencilCoeffs) -> np.ndarray:
    """Materialize A as a dense (N, N) float64 matrix (small meshes only)."""
    shape = coeffs.shape
    n = int(np.prod(shape))
    if coeffs.diag is None:
        A = np.eye(n, dtype=np.float64)
    else:
        A = np.diag(np.asarray(coeffs.diag, np.float64).ravel())
    idx = np.arange(n).reshape(shape)
    for name, cf in coeffs.diags.items():
        cf = np.asarray(cf, dtype=np.float64)
        off = name_offset(name, len(shape))
        src = idx
        for ax, o in enumerate(off):
            src = np.roll(src, -o, axis=ax)
        # zero out rows whose neighbor crosses the boundary
        valid = np.ones(shape, dtype=bool)
        for ax, o in enumerate(off):
            sl = [slice(None)] * len(shape)
            if o >= 1:
                sl[ax] = slice(-o, None)
                valid[tuple(sl)] = False
            elif o <= -1:
                sl[ax] = slice(0, -o)
                valid[tuple(sl)] = False
        rows = idx[valid].ravel()
        cols = src[valid].ravel()
        A[rows, cols] += cf[valid].ravel()
    return A


# ---------------------------------------------------------------------------
# Problem generators
# ---------------------------------------------------------------------------

def _default_spec(shape, spec: StencilSpec | None) -> StencilSpec:
    if spec is None:
        return StencilSpec("star", 1, len(shape))
    if spec.ndim != len(shape):
        raise ValueError(f"spec is {spec.ndim}-D but mesh shape {shape} is {len(shape)}-D")
    return spec


def poisson(shape: tuple[int, ...], dtype=jnp.float32,
            spec: StencilSpec | None = None) -> StencilCoeffs:
    """Jacobi-preconditioned constant-coefficient Laplacian-like operator.

    The raw operator has diagonal ``n_offsets`` and off-diagonals ``-1``;
    preconditioning by the diagonal gives unit diagonal and ``-1/n_offsets``
    off-diagonals — symmetric and weakly diagonally dominant for every spec
    (the classic 7-point model problem when ``spec`` is the default star r=1,
    the 27-point "full-neighborhood" Laplacian for ``BOX27``).
    """
    spec = _default_spec(shape, spec)
    c = -1.0 / spec.n_offsets
    return StencilCoeffs({n: jnp.full(shape, c, dtype=dtype) for n in spec.names})


def random_nonsymmetric(
    key: jax.Array,
    shape: tuple[int, ...],
    dtype=jnp.float32,
    *,
    dominance: float = 1.25,
    spec: StencilSpec | None = None,
) -> StencilCoeffs:
    """Random nonsymmetric diagonally-dominant stencil (BiCGStab's habitat).

    Off-diagonal magnitudes sum to ``1/dominance`` per row so the Jacobi-
    preconditioned matrix is strictly diagonally dominant => BiCGStab
    converges.  Signs are random => A is nonsymmetric, like the upwinded
    convection-diffusion systems MFIX produces (paper §VI).  Works for any
    spec in the family (star25 and box27 included).
    """
    names = _default_spec(shape, spec).names
    keys = jax.random.split(key, len(names) + 1)
    mags = {
        n: jax.random.uniform(k, shape, jnp.float32, 0.05, 1.0)
        for n, k in zip(names, keys[:-1])
    }
    total = sum(mags.values())
    signs = {
        n: jnp.where(jax.random.bernoulli(k, 0.5, shape), 1.0, -1.0)
        for n, k in zip(names, jax.random.split(keys[-1], len(names)))
    }
    return StencilCoeffs(
        {n: (signs[n] * mags[n] / (dominance * total)).astype(dtype) for n in names}
    )


def convection_diffusion(
    shape: tuple[int, ...],
    dtype=jnp.float32,
    *,
    peclet: float = 5.0,
) -> StencilCoeffs:
    """Upwinded convection-diffusion operator, Jacobi preconditioned.

    A deterministic nonsymmetric model of the paper's momentum equations:
    diffusion contributes -1 per face; a constant velocity field (1, 0.5,
    0.25) upwinds the convection term with cell Peclet number ``peclet``.
    """
    ndim = len(shape)
    vel = (1.0, 0.5, 0.25)[:ndim]
    names = DIAGS_3D if ndim == 3 else DIAGS_2D
    raw: dict[str, float] = {}
    diag = 0.0
    for ax, name_pair in enumerate(zip(names[0::2], names[1::2])):
        plus, minus = name_pair
        conv = peclet * vel[ax]
        # first-order upwind: flow in +ax direction biases the -ax neighbor
        raw[plus] = -1.0
        raw[minus] = -1.0 - conv
        diag += 2.0 + conv
    return StencilCoeffs(
        {n: jnp.full(shape, raw[n] / diag, dtype=dtype) for n in names}
    )


def heterogeneous_poisson(
    key: jax.Array,
    shape: tuple[int, ...],
    dtype=jnp.float32,
    *,
    contrast: float = 2.0,
    spec: StencilSpec | None = None,
) -> StencilCoeffs:
    """Raw (non-normalized) variable-coefficient diffusion operator.

    A log-normal cell diffusivity ``k = exp(contrast * N(0,1))`` couples
    each pair of neighbors with the face average ``(k_i + k_j)/2``; the
    stored main diagonal is the (variable) row sum of the couplings, with
    edge-replicated boundary faces so every row is weakly dominant.  This
    is the workload where Jacobi preconditioning (``M^-1 = D^-1``) does
    real work — the paper's operators arrive pre-normalized, this one does
    not.
    """
    spec = _default_spec(shape, spec)
    k = jnp.exp(contrast * jax.random.normal(key, shape, jnp.float32))

    def shift_edge(a, off):
        for axis, o in enumerate(off):
            if o == 0:
                continue
            pad = [(0, 0)] * a.ndim
            idx = [slice(None)] * a.ndim
            if o > 0:
                pad[axis] = (0, o)
                idx[axis] = slice(o, None)
            else:
                pad[axis] = (-o, 0)
                idx[axis] = slice(0, o)
            a = jnp.pad(a, pad, mode="edge")[tuple(idx)]
        return a

    couplings = {offset_name(o): (k + shift_edge(k, o)) / 2.0
                 for o in spec.offsets}
    diag = sum(couplings.values())
    return StencilCoeffs(
        {n: (-c).astype(dtype) for n, c in couplings.items()},
        diag=diag.astype(dtype))


# Central-difference second-derivative weights a_k (k = 1..r) of order 2r;
# a_0 is the center weight.  r=4 is the 8th-order arm of Jacquelin et al.'s
# 25-point seismic-RTM stencil.
_FD2_WEIGHTS = {
    1: (-2.0, (1.0,)),
    2: (-5.0 / 2.0, (4.0 / 3.0, -1.0 / 12.0)),
    3: (-49.0 / 18.0, (3.0 / 2.0, -3.0 / 20.0, 1.0 / 90.0)),
    4: (-205.0 / 72.0, (8.0 / 5.0, -1.0 / 5.0, 8.0 / 315.0, -1.0 / 560.0)),
}


def high_order_star(
    shape: tuple[int, ...],
    radius: int = 4,
    dtype=jnp.float32,
    *,
    dominance: float = 1.25,
) -> StencilCoeffs:
    """Seismic-flavored high-order star operator (Jacquelin et al.'s shape).

    Uses the order-2r central-difference second-derivative weights on each
    axis (r=4 => the 25-point star of the seismic RTM stencil), embedded in
    an implicit-timestep operator ``I - theta * Laplacian_2r`` and Jacobi
    preconditioned.  ``theta`` is chosen so the off-diagonal row sum is
    ``1/dominance`` — strictly diagonally dominant, so the solve converges
    while keeping the true sign structure of the FD weights (alternating
    along each arm).
    """
    if radius not in _FD2_WEIGHTS:
        raise ValueError(f"radius must be in {sorted(_FD2_WEIGHTS)}, got {radius}")
    spec = StencilSpec("star", radius, len(shape))
    _, arm = _FD2_WEIGHTS[radius]
    total = len(shape) * 2 * sum(abs(a) for a in arm)
    scale = 1.0 / (dominance * total)
    diags = {}
    for off in spec.offsets:
        dist = max(abs(o) for o in off)
        diags[offset_name(off)] = jnp.full(shape, -arm[dist - 1] * scale, dtype=dtype)
    return StencilCoeffs(diags)


def rhs_for_solution(coeffs: StencilCoeffs, x_true: jax.Array) -> jax.Array:
    """b = A @ x_true in float64-ish (f32) precision, for manufactured tests."""
    return apply_ref(coeffs.astype(jnp.float32), x_true.astype(jnp.float32))


def flops_per_point(ndim: int = 3) -> int:
    """SpMV flops per meshpoint: 6 mul + 6 add (3D, unit diagonal) = 12.

    Matches Table I: Matvec x2 per iteration = 24 of the 44 ops/meshpoint.
    """
    n_off = 2 * ndim
    return 2 * n_off


def words_per_point(ndim: int = 3) -> int:
    """Memory words touched per meshpoint per SpMV: 6 coeffs + v + u."""
    return 2 * ndim + 2


def spec_flops_per_point(spec: StencilSpec) -> int:
    """SpMV flops per meshpoint for any family member: mul+add per offset.

    star7 => 12 (Table I's 24/2), star25 => 48, box27 => 52.
    """
    return 2 * spec.n_offsets


def spec_words_per_point(spec: StencilSpec) -> int:
    """Memory words touched per meshpoint per SpMV: coeffs + v + u."""
    return spec.n_offsets + 2


def halo_words_per_spmv(spec: StencilSpec, block: tuple[int, ...],
                        split_axes: tuple[int, ...] = (0, 1)) -> int:
    """Words exchanged per SpMV by one shard: depth-r slabs on split axes.

    Counts both directions; for box stencils the sequential corner-carrying
    exchange also ships the already-received halo of earlier axes.
    """
    r = spec.radius
    words = 0
    padded = list(block)
    for ax in split_axes:
        slab = r
        for i, n in enumerate(padded):
            if i != ax:
                slab *= n
        words += 2 * slab
        if spec.needs_corners:
            padded[ax] += 2 * r
    return words
