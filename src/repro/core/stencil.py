"""7-point (3D) and 5-point (2D) stencil operators (paper §IV).

The matrix ``A`` of the discretized PDE has seven nonzero diagonals; after
diagonal (Jacobi) preconditioning the main diagonal is all ones, so only the
six off-diagonals are stored (paper: "we only store six other diagonals").
Coefficients are stored as one mesh-shaped array per diagonal, exactly the
per-core layout of Listing 1 (xp, xm, yp, ym, zp, zm) generalized from one
Z-pencil per core to one sub-volume per chip.

Boundary semantics are zero-Dirichlet: a shift that crosses the mesh edge
contributes zero (on CS-1 this was achieved by zero-padding the local
arrays; here by zero-fill of ``ppermute`` at fabric edges / ``jnp.pad``).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import Policy, F32

# Order matters and is shared with the Pallas kernel and the dense builder.
DIAGS_3D = ("xp", "xm", "yp", "ym", "zp", "zm")
DIAGS_2D = ("xp", "xm", "yp", "ym")

# Offset (in mesh coordinates) of the neighbor each diagonal reads.
OFFSETS = {
    "xp": (1, 0, 0), "xm": (-1, 0, 0),
    "yp": (0, 1, 0), "ym": (0, -1, 0),
    "zp": (0, 0, 1), "zm": (0, 0, -1),
}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StencilCoeffs:
    """Off-diagonal coefficient fields of a unit-diagonal stencil matrix.

    ``diags[name]`` has the mesh shape; entry ``diags['xp'][i,j,k]`` multiplies
    ``v[i+1,j,k]`` when computing row ``(i,j,k)`` of ``A @ v``.
    """

    diags: dict[str, jax.Array]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(self.diags)

    @property
    def shape(self) -> tuple[int, ...]:
        return next(iter(self.diags.values())).shape

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):
        return next(iter(self.diags.values())).dtype

    def astype(self, dtype) -> "StencilCoeffs":
        return StencilCoeffs({k: v.astype(dtype) for k, v in self.diags.items()})

    def tree_flatten(self):
        keys = tuple(sorted(self.diags))
        return tuple(self.diags[k] for k in keys), keys

    @classmethod
    def tree_unflatten(cls, keys, values):
        return cls(dict(zip(keys, values)))


def _shift(v: jax.Array, axis: int, offset: int) -> jax.Array:
    """v shifted so result[i] = v[i + offset] along ``axis``; zero fill."""
    if offset == 0:
        return v
    pad = [(0, 0)] * v.ndim
    if offset > 0:
        pad[axis] = (0, offset)
        return jnp.pad(v, pad)[
            tuple(slice(offset, None) if a == axis else slice(None) for a in range(v.ndim))
        ]
    pad[axis] = (-offset, 0)
    return jnp.pad(v, pad)[
        tuple(slice(0, offset) if a == axis else slice(None) for a in range(v.ndim))
    ]


def apply_ref(coeffs: StencilCoeffs, v: jax.Array, *, policy: Policy = F32) -> jax.Array:
    """Reference (single-address-space) u = A v.  Oracle for everything else.

    Follows the paper's arithmetic: the products and the 6 accumulating adds
    run in ``policy.compute`` (Table I counts these as half precision in the
    mixed policy); the unit diagonal contributes ``v`` directly.
    """
    c = policy.compute
    u = v.astype(c)
    for name, cf in coeffs.diags.items():
        off = OFFSETS[name][: v.ndim]
        axis = next(i for i, o in enumerate(off) if o != 0)
        u = u + cf.astype(c) * _shift(v, axis, off[axis]).astype(c)
    return u.astype(policy.storage)


def to_dense(coeffs: StencilCoeffs) -> np.ndarray:
    """Materialize A as a dense (N, N) float64 matrix (small meshes only)."""
    shape = coeffs.shape
    n = int(np.prod(shape))
    A = np.eye(n, dtype=np.float64)
    idx = np.arange(n).reshape(shape)
    for name, cf in coeffs.diags.items():
        cf = np.asarray(cf, dtype=np.float64)
        off = OFFSETS[name][: len(shape)]
        src = idx
        for ax, o in enumerate(off):
            src = np.roll(src, -o, axis=ax)
        # zero out rows whose neighbor crosses the boundary
        valid = np.ones(shape, dtype=bool)
        for ax, o in enumerate(off):
            if o == 1:
                sl = [slice(None)] * len(shape)
                sl[ax] = slice(-1, None)
                valid[tuple(sl)] = False
            elif o == -1:
                sl = [slice(None)] * len(shape)
                sl[ax] = slice(0, 1)
                valid[tuple(sl)] = False
        rows = idx[valid].ravel()
        cols = src[valid].ravel()
        A[rows, cols] += cf[valid].ravel()
    return A


# ---------------------------------------------------------------------------
# Problem generators
# ---------------------------------------------------------------------------

def poisson(shape: tuple[int, ...], dtype=jnp.float32) -> StencilCoeffs:
    """Jacobi-preconditioned 7-point (or 5-point) Laplacian.

    The raw operator has diagonal ``2*ndim`` and off-diagonals ``-1``;
    preconditioning by the diagonal gives unit diagonal and ``-1/(2*ndim)``
    off-diagonals — symmetric positive definite, the classic model problem.
    """
    names = DIAGS_3D if len(shape) == 3 else DIAGS_2D
    c = -1.0 / (2 * len(shape))
    return StencilCoeffs({n: jnp.full(shape, c, dtype=dtype) for n in names})


def random_nonsymmetric(
    key: jax.Array,
    shape: tuple[int, ...],
    dtype=jnp.float32,
    *,
    dominance: float = 1.25,
) -> StencilCoeffs:
    """Random nonsymmetric diagonally-dominant stencil (BiCGStab's habitat).

    Off-diagonal magnitudes sum to ``1/dominance`` per row so the Jacobi-
    preconditioned matrix is strictly diagonally dominant => BiCGStab
    converges.  Signs are random => A is nonsymmetric, like the upwinded
    convection-diffusion systems MFIX produces (paper §VI).
    """
    names = DIAGS_3D if len(shape) == 3 else DIAGS_2D
    keys = jax.random.split(key, len(names) + 1)
    mags = {
        n: jax.random.uniform(k, shape, jnp.float32, 0.05, 1.0)
        for n, k in zip(names, keys[:-1])
    }
    total = sum(mags.values())
    signs = {
        n: jnp.where(jax.random.bernoulli(k, 0.5, shape), 1.0, -1.0)
        for n, k in zip(names, jax.random.split(keys[-1], len(names)))
    }
    return StencilCoeffs(
        {n: (signs[n] * mags[n] / (dominance * total)).astype(dtype) for n in names}
    )


def convection_diffusion(
    shape: tuple[int, ...],
    dtype=jnp.float32,
    *,
    peclet: float = 5.0,
) -> StencilCoeffs:
    """Upwinded convection-diffusion operator, Jacobi preconditioned.

    A deterministic nonsymmetric model of the paper's momentum equations:
    diffusion contributes -1 per face; a constant velocity field (1, 0.5,
    0.25) upwinds the convection term with cell Peclet number ``peclet``.
    """
    ndim = len(shape)
    vel = (1.0, 0.5, 0.25)[:ndim]
    names = DIAGS_3D if ndim == 3 else DIAGS_2D
    raw: dict[str, float] = {}
    diag = 0.0
    for ax, name_pair in enumerate(zip(names[0::2], names[1::2])):
        plus, minus = name_pair
        conv = peclet * vel[ax]
        # first-order upwind: flow in +ax direction biases the -ax neighbor
        raw[plus] = -1.0
        raw[minus] = -1.0 - conv
        diag += 2.0 + conv
    return StencilCoeffs(
        {n: jnp.full(shape, raw[n] / diag, dtype=dtype) for n in names}
    )


def rhs_for_solution(coeffs: StencilCoeffs, x_true: jax.Array) -> jax.Array:
    """b = A @ x_true in float64-ish (f32) precision, for manufactured tests."""
    return apply_ref(coeffs.astype(jnp.float32), x_true.astype(jnp.float32))


def flops_per_point(ndim: int = 3) -> int:
    """SpMV flops per meshpoint: 6 mul + 6 add (3D, unit diagonal) = 12.

    Matches Table I: Matvec x2 per iteration = 24 of the 44 ops/meshpoint.
    """
    n_off = 2 * ndim
    return 2 * n_off


def words_per_point(ndim: int = 3) -> int:
    """Memory words touched per meshpoint per SpMV: 6 coeffs + v + u."""
    return 2 * ndim + 2
