"""Distributed BiCGStab (paper Alg. 1, §IV) and CG, with precision policies.

The solver is generic over two callables so the same loop runs in three
modes that share every line of algorithm logic:

* reference: ``apply`` = dense-shift oracle, ``dots`` = local reductions;
* SPMD:      ``apply`` = halo-exchange local apply, ``dots`` = psum over the
  fabric — the whole loop lives inside one ``shard_map`` so the collective
  schedule (this paper's subject) is exactly what we write;
* kernel:    ``apply``/``axpy`` swapped for the Pallas fused kernels.

Reduction schedule per iteration (paper counts 4 dot products):

    s = A p;                <r0, s>                      (sync point 1)
    y = A q;                <q, y>, <y, y>               (sync point 2)
    r+ = q - w y;           <r0, r+>, <r+, r+>           (sync point 3)

``fused_reductions=True`` (beyond-paper) batches each sync point into one
AllReduce => 3/iter; ``False`` is the paper's one-blocking-AllReduce-per-dot
=> 5/iter (incl. the convergence norm).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.halo import FabricAxes, local_apply, make_dots
from repro.core.precision import Policy, F32, MIXED
from repro.core.stencil import StencilCoeffs, apply_ref


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["x", "iterations", "rel_residual", "converged", "breakdown", "history"],
    meta_fields=[],
)
@dataclasses.dataclass
class SolveResult:
    x: jax.Array
    iterations: jax.Array          # int32
    rel_residual: jax.Array        # f32, recurrence residual at exit
    converged: jax.Array           # bool
    breakdown: jax.Array           # bool (rho or omega denominator vanished)
    history: jax.Array | None = None  # f32[maxiter] rel residuals (history mode)


_EPS = 1e-30


def _safe_div(num, den):
    ok = jnp.abs(den) > _EPS
    return jnp.where(ok, num / jnp.where(ok, den, 1.0), 0.0), ~ok


def _axpys(policy: Policy):
    """AXPY family in compute precision (paper Table I: 6 HP AXPYs/iter)."""
    c = policy.compute

    def axpy(a, x, y):  # y + a*x
        return (y.astype(c) + a.astype(c) * x.astype(c)).astype(policy.storage)

    def axpy2(a, x, b, y, z):  # z + a*x + b*y
        return (
            z.astype(c) + a.astype(c) * x.astype(c) + b.astype(c) * y.astype(c)
        ).astype(policy.storage)

    return axpy, axpy2


def bicgstab_loop(
    apply_A: Callable[[jax.Array], jax.Array],
    dots: Callable,
    b: jax.Array,
    x0: jax.Array | None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
    axpy=None,
    axpy2=None,
):
    """The algorithm body; composable inside jit/shard_map. Returns SolveResult."""
    default_axpy, default_axpy2 = _axpys(policy)
    axpy = axpy or default_axpy
    axpy2 = axpy2 or default_axpy2

    b = b.astype(policy.storage)
    if x0 is None:
        x0 = jnp.zeros_like(b)
        r0 = b
    else:
        x0 = x0.astype(policy.storage)
        r0 = axpy(jnp.float32(-1.0), apply_A(x0), b)

    (bnorm2,) = dots([(b, b)], policy)
    (rho0,) = dots([(r0, r0)], policy)

    def step(carry):
        i, x, r, p, rho, res2, conv, brk = carry
        s = apply_A(p)
        (r0s,) = dots([(r0, s)], policy)
        alpha, bad1 = _safe_div(rho, r0s)
        q = axpy(-alpha, s, r)
        y = apply_A(q)
        qy, yy = dots([(q, y), (y, y)], policy)
        omega, bad2 = _safe_div(qy, yy)
        x = axpy2(alpha, p, omega, q, x)
        r_new = axpy(-omega, y, q)
        rho_new, res2_new = dots([(r0, r_new), (r_new, r_new)], policy)
        beta_frac, bad3 = _safe_div(rho_new, rho)
        alpha_frac, bad4 = _safe_div(alpha, omega)
        beta = beta_frac * alpha_frac
        p = axpy(beta, axpy(-omega, s, p), r_new)
        conv = res2_new <= (tol * tol) * bnorm2
        brk = bad1 | bad2 | bad3 | bad4
        return i + 1, x, r_new, p, rho_new, res2_new, conv, brk

    init = (
        jnp.int32(0), x0, r0, r0, rho0, rho0,
        rho0 <= (tol * tol) * bnorm2, jnp.bool_(False),
    )

    if record_history:
        def scan_body(carry, _):
            i, x, r, p, rho, res2, conv, brk = carry
            active = ~(conv | brk)
            new = step(carry)
            carry = jax.tree.map(
                lambda n, o: jnp.where(active, n, o), new, carry
            )
            rel = jnp.sqrt(carry[5] / jnp.maximum(bnorm2, _EPS))
            return carry, rel

        final, hist = jax.lax.scan(scan_body, init, None, length=maxiter)
        i, x, r, p, rho, res2, conv, brk = final
        rel = jnp.sqrt(res2 / jnp.maximum(bnorm2, _EPS))
        return SolveResult(x, i, rel, conv, brk, history=hist)

    def cond(carry):
        i, *_rest, conv, brk = carry
        return (i < maxiter) & ~conv & ~brk

    final = jax.lax.while_loop(cond, step, init)
    i, x, r, p, rho, res2, conv, brk = final
    rel = jnp.sqrt(res2 / jnp.maximum(bnorm2, _EPS))
    return SolveResult(x, i, rel, conv, brk)


# ---------------------------------------------------------------------------
# Reference (single address space) entry point
# ---------------------------------------------------------------------------

def _local_dots(pairs, policy: Policy):
    return jnp.stack([policy.dot(a, b) for a, b in pairs])


def solve_ref(
    coeffs: StencilCoeffs,
    b: jax.Array,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
) -> SolveResult:
    """Single-device oracle solve (used by tests and small examples)."""
    cf = coeffs.astype(policy.storage)
    apply_A = functools.partial(apply_ref, cf, policy=policy)
    return bicgstab_loop(
        apply_A, _local_dots, b, x0,
        tol=tol, maxiter=maxiter, policy=policy, record_history=record_history,
    )


def solve_ref_fused(
    coeffs: StencilCoeffs,
    b: jax.Array,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    interpret: bool = True,
):
    """BiCGStab evaluated entirely through the fused Pallas schedule
    (EXPERIMENTS §Perf stencil v3): SpMV+dot epilogues and fused
    update+dot passes — 31 words/meshpoint/iteration instead of 42.

    Single-block (per-chip) reference; the distributed solver composes the
    same kernels via ``apply_impl=pallas_local_apply``.  Python loop (not
    lax.while) because pallas_call is re-traced per call in interpret mode.
    """
    from repro.kernels.fused_iter import update_p, update_xr_dots
    from repro.kernels.stencil7.fused import stencil7_dot, stencil7_two_dots

    x = jnp.zeros_like(b)
    r = b
    p = b
    r0 = b
    bnorm2 = float(jnp.vdot(b.astype(jnp.float32), b.astype(jnp.float32)))
    rho = jnp.float32(bnorm2)
    n_iter = 0
    rel = 1.0
    for n_iter in range(1, maxiter + 1):
        s, r0s = stencil7_dot(coeffs, p, r0, interpret=interpret)   # pass 1
        alpha = rho / r0s
        q = r - alpha.astype(r.dtype) * s                            # pass 2
        y, qy, yy = stencil7_two_dots(coeffs, q, interpret=interpret)  # pass 3
        omega = qy / yy
        x, r, rho_new, rr = update_xr_dots(alpha, omega, x, p, q, y, r0,
                                           interpret=interpret)      # pass 4
        beta = (alpha / omega) * (rho_new / rho)
        p = update_p(beta, omega, r, p, s, interpret=interpret)      # pass 5
        rho = rho_new
        rel = float(jnp.sqrt(rr / bnorm2))
        if rel < tol:
            break
    return SolveResult(x, jnp.int32(n_iter), jnp.float32(rel),
                       jnp.bool_(rel < tol), jnp.bool_(False))


# ---------------------------------------------------------------------------
# Distributed (shard_map) entry point — the paper's implementation
# ---------------------------------------------------------------------------

def solve_distributed(
    mesh,
    coeffs: StencilCoeffs,
    b: jax.Array,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = MIXED,
    fused_reductions: bool = True,
    overlap_halo: bool = True,
    record_history: bool = False,
    apply_impl: Callable | None = None,
) -> SolveResult:
    """BiCGStab with the entire iteration inside one ``shard_map``.

    The fabric sees exactly the paper's traffic: one bidirectional face
    exchange per mesh axis per SpMV (2 SpMV/iter) and 3 (fused) or 5
    (paper-faithful separate) scalar AllReduces per iteration.

    ``apply_impl`` lets callers swap the local SpMV for a Pallas kernel.
    """
    fabric = FabricAxes.from_mesh(mesh)
    spec = fabric.spec(b.ndim)
    dots = make_dots(fabric, fused=fused_reductions)
    cf = coeffs.astype(policy.storage)

    impl = apply_impl or local_apply

    def solve_fn(cf_local, b_local, x0_local):
        apply_A = lambda v: impl(cf_local, v, fabric, policy=policy, overlap=overlap_halo)
        return bicgstab_loop(
            apply_A, dots, b_local, x0_local,
            tol=tol, maxiter=maxiter, policy=policy, record_history=record_history,
        )

    scalar = P()
    out_specs = SolveResult(
        x=spec, iterations=scalar, rel_residual=scalar,
        converged=scalar, breakdown=scalar,
        history=(scalar if record_history else None),
    )
    if x0 is None:
        x0 = jnp.zeros_like(b)
    mapped = shard_map(
        solve_fn, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=out_specs,
        # Pallas apply_impls produce ShapeDtypeStructs without vma metadata;
        # out_specs above are explicit, so the vma checker adds nothing here.
        check_vma=False,
    )
    return mapped(cf, b, x0)


def make_iteration_fn(
    mesh,
    *,
    policy: Policy = MIXED,
    fused_reductions: bool = True,
    overlap_halo: bool = True,
    apply_impl: Callable | None = None,
):
    """One BiCGStab iteration as a standalone SPMD function.

    This is the unit the paper measures (28.1 us/iter on the CS-1) and the
    unit the dry-run lowers for the roofline: 2 halo-exchange SpMVs, 6 AXPYs,
    4 inner products, 3 (fused) or 5 (separate) AllReduce points.

    Signature: (coeffs, x, r, p, r0, rho) -> (x, r, p, rho, res2).
    """
    fabric = FabricAxes.from_mesh(mesh)
    dots = make_dots(fabric, fused=fused_reductions)
    impl = apply_impl or local_apply
    axpy, axpy2 = _axpys(policy)

    def iteration(cf, x, r, p, r0, rho):
        apply_A = lambda v: impl(cf, v, fabric, policy=policy, overlap=overlap_halo)
        s = apply_A(p)
        (r0s,) = dots([(r0, s)], policy)
        alpha, _ = _safe_div(rho, r0s)
        q = axpy(-alpha, s, r)
        y = apply_A(q)
        qy, yy = dots([(q, y), (y, y)], policy)
        omega, _ = _safe_div(qy, yy)
        x = axpy2(alpha, p, omega, q, x)
        r_new = axpy(-omega, y, q)
        rho_new, res2 = dots([(r0, r_new), (r_new, r_new)], policy)
        beta_frac, _ = _safe_div(rho_new, rho)
        alpha_frac, _ = _safe_div(alpha, omega)
        p = axpy(beta_frac * alpha_frac, axpy(-omega, s, p), r_new)
        return x, r_new, p, rho_new, res2

    spec = fabric.spec(3)
    scalar = P()
    return shard_map(
        iteration, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, scalar),
        out_specs=(spec, spec, spec, scalar, scalar),
        check_vma=False,   # see solve_distributed: Pallas apply_impls
    )


# ---------------------------------------------------------------------------
# Iterative refinement (beyond paper — §VI-B discussion made concrete)
# ---------------------------------------------------------------------------

def solve_refined(
    coeffs: StencilCoeffs,
    b: jax.Array,
    *,
    mesh=None,
    outer_iters: int = 4,
    inner_maxiter: int = 60,
    inner_tol: float = 1e-3,
    inner_policy: Policy = MIXED,
    tol: float = 1e-6,
):
    """fp32-accurate solutions from a bf16 inner solver.

    The paper observes the mixed-precision residual plateaus near machine-eps
    (Fig. 9) and points at iterative refinement [Carson-Higham] as the fix.
    We implement it: residuals and the solution accumulate in f32; each
    correction solve runs entirely in the 16-bit policy.
    """
    cf32 = coeffs.astype(jnp.float32)

    def inner(rhs):
        if mesh is None:
            return solve_ref(coeffs, rhs, tol=inner_tol, maxiter=inner_maxiter,
                             policy=inner_policy)
        return solve_distributed(mesh, coeffs, rhs, tol=inner_tol,
                                 maxiter=inner_maxiter, policy=inner_policy)

    if mesh is None:
        apply32 = functools.partial(apply_ref, cf32, policy=F32)
    else:
        from repro.core.halo import global_apply
        apply32 = functools.partial(global_apply, mesh, cf32, policy=F32)

    x = jnp.zeros_like(b, dtype=jnp.float32)
    bnorm = jnp.linalg.norm(b.astype(jnp.float32))
    rels = []
    for _ in range(outer_iters):
        r = b.astype(jnp.float32) - apply32(x)
        rels.append(jnp.linalg.norm(r) / jnp.maximum(bnorm, _EPS))
        d = inner(r.astype(inner_policy.storage))
        x = x + d.x.astype(jnp.float32)
    r = b.astype(jnp.float32) - apply32(x)
    rels.append(jnp.linalg.norm(r) / jnp.maximum(bnorm, _EPS))
    return x, jnp.stack(rels)


# ---------------------------------------------------------------------------
# CG (for the symmetric/HPCG-flavored comparisons)
# ---------------------------------------------------------------------------

def cg_loop(apply_A, dots, b, x0=None, *, tol=1e-6, maxiter=200, policy=F32):
    axpy, _ = _axpys(policy)
    b = b.astype(policy.storage)
    x = jnp.zeros_like(b) if x0 is None else x0.astype(policy.storage)
    r = b if x0 is None else axpy(jnp.float32(-1.0), apply_A(x), b)
    (bnorm2,) = dots([(b, b)], policy)
    (rho,) = dots([(r, r)], policy)

    def cond(c):
        i, x, r, p, rho, conv = c
        return (i < maxiter) & ~conv

    def step(c):
        i, x, r, p, rho, conv = c
        ap = apply_A(p)
        (pap,) = dots([(p, ap)], policy)
        alpha, _ = _safe_div(rho, pap)
        x = axpy(alpha, p, x)
        r = axpy(-alpha, ap, r)
        (rho_new,) = dots([(r, r)], policy)
        beta, _ = _safe_div(rho_new, rho)
        p = axpy(beta, p, r)
        return i + 1, x, r, p, rho_new, rho_new <= (tol * tol) * bnorm2

    i, x, r, p, rho, conv = jax.lax.while_loop(
        cond, step, (jnp.int32(0), x, r, r, rho, rho <= (tol * tol) * bnorm2)
    )
    rel = jnp.sqrt(rho / jnp.maximum(bnorm2, _EPS))
    return SolveResult(x, i, rel, conv, jnp.bool_(False))


def cg_ref(coeffs: StencilCoeffs, b, **kw):
    policy = kw.get("policy", F32)
    cf = coeffs.astype(policy.storage)
    return cg_loop(functools.partial(apply_ref, cf, policy=policy), _local_dots, b,
                   **{k: v for k, v in kw.items() if k != "x0"})
