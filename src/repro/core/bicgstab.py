"""Solver drivers: wire mesh + operator backend + preconditioner + solver.

This module is the glue layer (and the historical import surface — the
algorithm bodies moved to ``core/solvers/``, the SpMV backends to
``core/operator.py``, preconditioning to ``core/precond.py``):

* :func:`solve_ref`          — single-address-space solve (oracle);
* :func:`solve_distributed`  — the paper's run: the whole Krylov iteration
  inside one ``shard_map``, any registered solver x backend x precond;
* :func:`make_iteration_fn`  — one SPMD iteration (the unit the paper
  measures and the dry-run lowers);
* :func:`solve_refined`      — bf16 inner solves + f32 iterative refinement;
* :func:`solve_ref_fused`    — single-block BiCGStab through the fused
  stencil7 dot-epilogue kernels (the per-chip reference schedule).

Legacy names (``bicgstab_loop``, ``cg_loop``, ``SolveResult``, ...) are
re-exported so existing callers and tests keep working.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.comm import SCHEDULES, get_schedule  # noqa: F401
from repro.core.halo import FabricAxes
from repro.core.operator import BACKENDS, make_operator  # noqa: F401
from repro.core.precision import Policy, F32, MIXED
from repro.core.precond import PrecondConfig, build_precond, get_precond_config
from repro.core.solvers import SOLVERS, get_solver  # noqa: F401
from repro.core.solvers.bicgstab import bicgstab_fused_loop, bicgstab_loop  # noqa: F401
from repro.core.solvers.cg import cg_loop  # noqa: F401
from repro.core.solvers.common import (  # noqa: F401
    EPS as _EPS,
    SolveResult,
    axpy_family as _axpys,
    local_dots as _local_dots,
    safe_div as _safe_div,
)
from repro.core.stencil import StencilCoeffs, apply_ref


# ---------------------------------------------------------------------------
# Reference (single address space) entry point
# ---------------------------------------------------------------------------

def solve_ref(
    coeffs: StencilCoeffs,
    b: jax.Array,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = F32,
    record_history: bool = False,
    solver: str = "bicgstab",
    backend: str = "reference",
    precond: str | PrecondConfig | None = None,
    schedule: str | None = None,
) -> SolveResult:
    """Single-device oracle solve (used by tests and small examples).

    ``backend="pallas"`` runs the same solve through the fused kernels on a
    1x1 fabric (all collectives degenerate) — the single-block fused path.
    ``schedule`` picks the comm schedule for the distributed backends
    (degenerate here, but the apply structure is exercised).
    """
    op = make_operator(backend, coeffs, policy=policy, schedule=schedule)
    M = build_precond(get_precond_config(precond), op)
    return get_solver(solver)(
        op, b, x0, tol=tol, maxiter=maxiter, policy=policy,
        record_history=record_history, precond=M)


def cg_ref(coeffs: StencilCoeffs, b, **kw):
    """CG oracle (kept for the historical call sites)."""
    return solve_ref(coeffs, b, solver="cg",
                     **{k: v for k, v in kw.items() if k != "x0"})


def solve_ref_fused(
    coeffs: StencilCoeffs,
    b: jax.Array,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    interpret: bool | None = None,
):
    """BiCGStab evaluated entirely through the fused Pallas schedule
    (EXPERIMENTS §Perf stencil v3): SpMV+dot epilogues and fused
    update+dot passes — 31 words/meshpoint/iteration instead of 42.

    Single-block (per-chip) reference; the distributed solver composes the
    same vector kernels via ``backend="pallas"``.  Python loop (not
    lax.while) because pallas_call is re-traced per call in interpret mode.
    """
    from repro.compat import resolve_interpret
    from repro.kernels.fused_iter import update_p, update_xr_dots
    from repro.kernels.stencil_nd.fused import stencil7_dot, stencil7_two_dots

    interpret = resolve_interpret(interpret)
    x = jnp.zeros_like(b)
    r = b
    p = b
    r0 = b
    bnorm2 = float(jnp.vdot(b.astype(jnp.float32), b.astype(jnp.float32)))
    rho = jnp.float32(bnorm2)
    n_iter = 0
    rel = 1.0
    for n_iter in range(1, maxiter + 1):
        s, r0s = stencil7_dot(coeffs, p, r0, interpret=interpret)   # pass 1
        alpha = rho / r0s
        q = r - alpha.astype(r.dtype) * s                            # pass 2
        y, qy, yy = stencil7_two_dots(coeffs, q, interpret=interpret)  # pass 3
        omega = qy / yy
        x, r, rho_new, rr = update_xr_dots(alpha, omega, x, p, q, y, r0,
                                           interpret=interpret)      # pass 4
        beta = (alpha / omega) * (rho_new / rho)
        p = update_p(beta, omega, r, p, s, interpret=interpret)      # pass 5
        rho = rho_new
        rel = float(jnp.sqrt(rr / bnorm2))
        if rel < tol:
            break
    return SolveResult(x, jnp.int32(n_iter), jnp.float32(rel),
                       jnp.bool_(rel < tol), jnp.bool_(False))


# ---------------------------------------------------------------------------
# Distributed (shard_map) entry point — the paper's implementation
# ---------------------------------------------------------------------------

def solve_distributed(
    mesh,
    coeffs: StencilCoeffs,
    b: jax.Array,
    x0: jax.Array | None = None,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    policy: Policy = MIXED,
    fused_reductions: bool = True,
    overlap_halo: bool | None = None,
    schedule: str | None = None,
    record_history: bool = False,
    solver: str = "bicgstab",
    backend: str = "spmd",
    precond: str | PrecondConfig | None = None,
    interpret: bool | None = None,
    apply_impl: Callable | None = None,
) -> SolveResult:
    """A Krylov solve with the entire iteration inside one ``shard_map``.

    The fabric sees exactly the paper's traffic: one bidirectional face
    exchange per mesh axis per SpMV and 3 (fused) or 5 (paper-faithful
    separate) scalar AllReduces per BiCGStab iteration — 1 with the
    pipelined solvers (``solver="pipelined_bicgstab"/"pipelined_cg"``).
    With ``backend="pallas"`` the local work additionally runs as the fused
    stencil + vector-update Pallas kernels.

    ``schedule`` ("blocking" | "overlap", ``core.comm.SCHEDULES``) picks
    the halo schedule — ``overlap`` issues the ppermutes first and hides
    them under the interior apply, bit-identical to ``blocking``.  The
    legacy ``overlap_halo`` boolean spells the same choice and loses ties.

    ``precond`` ("none" | "jacobi" | "chebyshev" | a PrecondConfig) applies
    on the right, so the collective schedule is unchanged.  ``apply_impl``
    is the legacy hook swapping the local SpMV for a custom kernel.

    Block (many-RHS) solves: pass ``b`` with a leading batch axis
    ``(B,) + coeffs.shape``.  The batch axis is replicated (each shard owns
    its block of every RHS), halo slabs of all B RHS ride each ppermute
    message, every sync point reduces the stacked ``[k, B]`` partials in
    one AllReduce, and the returned SolveResult carries per-RHS ``[B]``
    iteration counts / flags / residuals.  The collective count per
    iteration is independent of B.
    """
    sched = get_schedule(schedule if schedule is not None else overlap_halo)
    fabric = FabricAxes.from_mesh(mesh)
    if backend == "reference" and mesh.devices.size > 1:
        # the reference backend has no halo exchange and local-only dots:
        # inside shard_map each shard would silently solve an unrelated
        # zero-Dirichlet sub-problem
        raise ValueError(
            "backend='reference' is single-address-space only; use "
            "backend='spmd' or 'pallas' on a multi-device mesh "
            "(or solve_ref on the undistributed arrays)")
    nb = b.ndim - coeffs.ndim       # leading batch (many-RHS) axes
    spec = fabric.spec(coeffs.ndim, n_batch=nb)
    cf_spec = fabric.spec(coeffs.ndim)
    cf = coeffs.astype(policy.storage)
    pconf = get_precond_config(precond)
    solver_fn = get_solver(solver)

    def solve_fn(cf_local, b_local, x0_local):
        op = make_operator(
            backend, cf_local, fabric, policy=policy,
            schedule=sched, fused_reductions=fused_reductions,
            interpret=interpret)
        if apply_impl is not None:
            op = op.with_apply(lambda v: apply_impl(
                op.coeffs, v, fabric, policy=policy, overlap=sched.overlap_halo))
        M = build_precond(pconf, op)
        return solver_fn(op, b_local, x0_local, tol=tol, maxiter=maxiter,
                         policy=policy, record_history=record_history,
                         precond=M)

    scalar = P()
    out_specs = SolveResult(
        x=spec, iterations=scalar, rel_residual=scalar,
        converged=scalar, breakdown=scalar,
        history=(scalar if record_history else None),
    )
    if x0 is None:
        x0 = jnp.zeros_like(b)
    mapped = shard_map(
        solve_fn, mesh=mesh,
        in_specs=(cf_spec, spec, spec),
        out_specs=out_specs,
        # Pallas applies produce ShapeDtypeStructs without vma metadata;
        # out_specs above are explicit, so the vma checker adds nothing here.
        check_vma=False,
    )
    return mapped(cf, b, x0)


def make_iteration_fn(
    mesh,
    *,
    policy: Policy = MIXED,
    fused_reductions: bool = True,
    overlap_halo: bool | None = None,
    schedule: str | None = None,
    backend: str = "spmd",
    interpret: bool | None = None,
    apply_impl: Callable | None = None,
):
    """One BiCGStab iteration as a standalone SPMD function.

    This is the unit the paper measures (28.1 us/iter on the CS-1) and the
    unit the dry-run lowers for the roofline: 2 halo-exchange SpMVs, 6 AXPYs,
    4 inner products, 3 (fused) or 5 (separate) AllReduce points.  With
    ``backend="pallas"`` the body is the fused-kernel dataflow, so lowering
    it shows the 3-AllReduce schedule of the wired fused iteration.

    Signature: (coeffs, x, r, p, r0, rho) -> (x, r, p, rho, res2).
    """
    from repro.core.solvers.common import safe_div

    sched = get_schedule(schedule if schedule is not None else overlap_halo)
    fabric = FabricAxes.from_mesh(mesh)
    if backend == "reference" and mesh.devices.size > 1:
        raise ValueError(
            "backend='reference' is single-address-space only; use "
            "backend='spmd' or 'pallas' on a multi-device mesh")

    def iteration(cf, x, r, p, r0, rho):
        op = make_operator(
            backend, cf, fabric, policy=policy,
            schedule=sched, fused_reductions=fused_reductions,
            interpret=interpret)
        if apply_impl is not None:
            op = op.with_apply(lambda v: apply_impl(
                op.coeffs, v, fabric, policy=policy, overlap=sched.overlap_halo))
        axpy, axpy2 = _axpys(policy)
        if op.fused is not None:
            f = op.fused
            st = policy.storage
            s = op.apply(p)
            (r0s,) = op.reduce_partials([f.dot_partial(r0, s)])
            alpha, _ = safe_div(rho, r0s)
            q_in = r - alpha.astype(st) * s
            y = op.apply(q_in)
            q, qy, yy = f.update_q_dots(alpha, r, s, y)
            qy, yy = op.reduce_partials([qy, yy])
            omega, _ = safe_div(qy, yy)
            x, r_new, r0r, rr = f.update_xr_dots(alpha, omega, x, p, q, y, r0)
            rho_new, res2 = op.reduce_partials([r0r, rr])
            beta_frac, _ = safe_div(rho_new, rho)
            alpha_frac, _ = safe_div(alpha, omega)
            p = f.update_p(beta_frac * alpha_frac, omega, r_new, p, s)
            return x, r_new, p, rho_new, res2
        s = op.apply(p)
        (r0s,) = op.dots([(r0, s)], policy)
        alpha, _ = safe_div(rho, r0s)
        q = axpy(-alpha, s, r)
        y = op.apply(q)
        qy, yy = op.dots([(q, y), (y, y)], policy)
        omega, _ = safe_div(qy, yy)
        x = axpy2(alpha, p, omega, q, x)
        r_new = axpy(-omega, y, q)
        rho_new, res2 = op.dots([(r0, r_new), (r_new, r_new)], policy)
        beta_frac, _ = safe_div(rho_new, rho)
        alpha_frac, _ = safe_div(alpha, omega)
        p = axpy(beta_frac * alpha_frac, axpy(-omega, s, p), r_new)
        return x, r_new, p, rho_new, res2

    spec = fabric.spec(3)
    scalar = P()
    return shard_map(
        iteration, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, scalar),
        out_specs=(spec, spec, spec, scalar, scalar),
        check_vma=False,   # see solve_distributed: Pallas applies
    )


# ---------------------------------------------------------------------------
# Iterative refinement (beyond paper — §VI-B discussion made concrete)
# ---------------------------------------------------------------------------

def solve_refined(
    coeffs: StencilCoeffs,
    b: jax.Array,
    *,
    mesh=None,
    outer_iters: int = 4,
    inner_maxiter: int = 60,
    inner_tol: float = 1e-3,
    inner_policy: Policy = MIXED,
    tol: float = 1e-6,
):
    """fp32-accurate solutions from a bf16 inner solver.

    The paper observes the mixed-precision residual plateaus near machine-eps
    (Fig. 9) and points at iterative refinement [Carson-Higham] as the fix.
    We implement it: residuals and the solution accumulate in f32; each
    correction solve runs entirely in the 16-bit policy.
    """
    cf32 = coeffs.astype(jnp.float32)

    def inner(rhs):
        if mesh is None:
            return solve_ref(coeffs, rhs, tol=inner_tol, maxiter=inner_maxiter,
                             policy=inner_policy)
        return solve_distributed(mesh, coeffs, rhs, tol=inner_tol,
                                 maxiter=inner_maxiter, policy=inner_policy)

    if mesh is None:
        apply32 = functools.partial(apply_ref, cf32, policy=F32)
    else:
        from repro.core.halo import global_apply
        apply32 = functools.partial(global_apply, mesh, cf32, policy=F32)

    x = jnp.zeros_like(b, dtype=jnp.float32)
    bnorm = jnp.linalg.norm(b.astype(jnp.float32))
    rels = []
    for _ in range(outer_iters):
        r = b.astype(jnp.float32) - apply32(x)
        rels.append(jnp.linalg.norm(r) / jnp.maximum(bnorm, _EPS))
        d = inner(r.astype(inner_policy.storage))
        x = x + d.x.astype(jnp.float32)
    r = b.astype(jnp.float32) - apply32(x)
    rels.append(jnp.linalg.norm(r) / jnp.maximum(bnorm, _EPS))
    return x, jnp.stack(rels)
