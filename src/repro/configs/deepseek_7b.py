"""deepseek-7b [dense] — llama-arch MHA [arXiv:2401.02954; hf].

30L d_model=4096 32H (kv=32, i.e. full MHA) d_ff=11008 vocab=102400;
d_head=128; untied head; SwiGLU; RMSNorm.
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek_7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab=102400,
    period=(LayerSpec(kind="attn"),),
    rope_theta=1e4,
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
)

SMOKE = ArchConfig(
    name="deepseek_7b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="attn"),),
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    moe_group_size=16,
)
