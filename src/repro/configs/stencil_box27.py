"""27-point box stencil cells: the full-neighborhood cube workload.

The box27 shape (all 26 neighbors of the 3x3x3 cube) is the stress case
for the halo machinery — unlike star stencils it reads edge and corner
halo values, which :func:`repro.core.halo.gather_halo` supplies through
the corner-carrying sequential exchange.  It shows up in trilinear FEM
mass/stiffness matrices, 27-point HPCG-style smoothers, and is one of the
kernels of Belli & De Sensi's *Stencil Computations on Cerebras Wafer-Scale
Engine* study of this paper's hardware lineage.
"""

from __future__ import annotations

from repro.configs.stencil_star25_seismic import StencilFamilyCell

BOX27_CELLS = {
    "box_smoke": StencilFamilyCell("box_smoke", (24, 24, 16), "box27",
                                   policy="f32", problem="random"),
    "box_chip": StencilFamilyCell("box_chip", (96, 96, 256), "box27",
                                  problem="random"),
    # full-neighborhood SPD smoother cell: CG through the Pallas-fused
    # backend (the box27 corner-halo path feeding the fused kernels)
    "box_cg_pallas": StencilFamilyCell("box_cg_pallas", (24, 24, 16), "box27",
                                       policy="f32", problem="poisson",
                                       solver="cg", backend="pallas"),
}


def ops_per_meshpoint_box27() -> dict:
    """Per-iteration per-meshpoint counts, Table-I style, for box27."""
    return {
        "matvec_hp_add": 52, "matvec_hp_mul": 52,
        "dot_hp_mul": 4, "dot_sp_add": 4,
        "axpy_hp_add": 6, "axpy_hp_mul": 6,
        "total": 124,
    }
