"""Named CFD application cells (paper §VI: the MFIX-class workload).

Mirrors the ``configs/stencil_*.py`` pattern: a cell fixes the scenario,
grid, physics, and which registry entries (solver/backend/precond) the
inner solves route through, so benchmarks and tests can name a workload
instead of re-assembling flags.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CFDCell:
    name: str
    scenario: str                   # "cavity" | "channel"
    n: int
    reynolds: float
    solver: str = "bicgstab"
    backend: str = "spmd"
    precond: str = "none"
    policy: str = "f32"
    normalize: bool = True          # False => raw aP rows (jacobi is real work)
    dt: float | None = None         # None => steady
    n_steps: int = 0                # transient steps when dt is set
    schedule: str = "overlap"       # halo schedule (core.comm.SCHEDULES)
    p_solver: str | None = None     # pressure-solve override (default: solver)


CFD_CELLS = {
    # the Ghia et al. validation flow (paper Figs. 7-8 run this cavity);
    # unit-diagonal rows (the paper's scheme) — jacobi is the identity here,
    # cavity_raw_jacobi below is where the preconditioner does real work
    "cavity_ghia": CFDCell("cavity_ghia", "cavity", n=32, reynolds=100.0),
    # raw-row variant: the registry Jacobi does the paper's normalization
    "cavity_raw_jacobi": CFDCell("cavity_raw_jacobi", "cavity", n=32,
                                 reynolds=100.0, precond="jacobi",
                                 normalize=False),
    # impulsively-started transient cavity (checkpointed spin-up)
    "cavity_spinup": CFDCell("cavity_spinup", "cavity", n=32, reynolds=100.0,
                             dt=0.05, n_steps=100),
    # inflow/outflow channel toward the developed profile
    "channel_develop": CFDCell("channel_develop", "channel", n=24,
                               reynolds=50.0, dt=0.05, n_steps=80),
    # communication-lean cavity: overlapped halos in every inner SpMV and
    # the single-AllReduce pipelined solver on the (iteration-dominant)
    # pressure-correction system
    "cavity_pipelined": CFDCell("cavity_pipelined", "cavity", n=32,
                                reynolds=100.0, schedule="overlap",
                                p_solver="pipelined_bicgstab"),
    "smoke": CFDCell("smoke", "cavity", n=12, reynolds=100.0),
}


def build(cell: CFDCell):
    """Instantiate (CFDConfig, SolverOptions, TransientConfig|None)."""
    from repro.apps.cfd import CFDConfig, SolverOptions, TransientConfig
    from repro.core.precision import get_policy

    cfg = CFDConfig(n=cell.n, reynolds=cell.reynolds, scenario=cell.scenario,
                    policy=get_policy(cell.policy))
    opts = SolverOptions(solver=cell.solver, backend=cell.backend,
                         precond=cell.precond, normalize=cell.normalize,
                         schedule=cell.schedule, p_solver=cell.p_solver)
    tcfg = (TransientConfig(dt=cell.dt, n_steps=cell.n_steps)
            if cell.dt is not None else None)
    return cfg, opts, tcfg
