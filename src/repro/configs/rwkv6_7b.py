"""rwkv6-7b "Finch" [ssm] — data-dependent decay, attention-free
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b].

32L d_model=4096 d_ff=14336 vocab=65536; head_size 64 (64 heads).  Fully
sub-quadratic: long_500k runs (O(1) state per layer).  LayerNorm per the
RWKV family.
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6_7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # d_model / head_size
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    period=(LayerSpec(kind="rwkv"),),
    rwkv_head_size=64,
    tie_embeddings=False,
    norm="layernorm",
    act="swiglu",          # unused (channel-mix has its own FFN)
)

SMOKE = ArchConfig(
    name="rwkv6_7b_smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="rwkv"),),
    rwkv_head_size=16,
    tie_embeddings=False,
    norm="layernorm",
    act="swiglu",
    moe_group_size=16,
)
