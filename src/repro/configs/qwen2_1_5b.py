"""qwen2-1.5b [dense] — GQA + QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; d_head=128;
QKV projections carry biases; tied embeddings.
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2_1_5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    period=(LayerSpec(kind="attn"),),
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
)

SMOKE = ArchConfig(
    name="qwen2_1_5b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="attn"),),
    qkv_bias=True,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    moe_group_size=16,
)
