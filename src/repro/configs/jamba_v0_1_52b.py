"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887; hf:ai21labs/Jamba-v0.1].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Period of 8 layers:
attention at position 4, Mamba elsewhere; MoE (16 experts, top-2, per-expert
ff=14336) on odd positions (every other layer).  Fully sub-quadratic in its
Mamba layers; the sparse attention layers make long_500k run with the
sequence-sharded flash-decode path (the paper's partial-reduction AllReduce).
16 experts divide the model axis => true expert parallelism.
"""

from repro.models.transformer import ArchConfig, LayerSpec

_PERIOD = tuple(
    LayerSpec(kind="attn" if i == 4 else "mamba", moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=65536,
    period=_PERIOD,
    n_experts=16,
    top_k=2,
    d_ff_expert=14336,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=1e4,
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    # 104 GB bf16 weights: FSDP-style spread over data axes (6.5 -> 0.4
    # GB/chip) — also what makes long_500k decode 15.6x faster (§Perf).
    rules=(
        ("expert_ff", ("model", "data")),
        ("ff", ("model", "data")),
        ("heads_flat", ("model", "data")),
        ("kv_seq", ("model", "data")),
    ),
)

SMOKE = ArchConfig(
    name="jamba_v0_1_52b_smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=tuple(
        LayerSpec(kind="attn" if i == 4 else "mamba", moe=(i % 2 == 1))
        for i in range(8)
    ),
    n_experts=4,
    top_k=2,
    d_ff_expert=32,
    mamba_d_state=4,
    mamba_d_conv=4,
    mamba_expand=2,
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    moe_group_size=16,
)
