"""gemma3-12b [dense] — 5:1 local:global interleave [hf:google/gemma-3-12b-pt].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.  Five sliding-window
(1024) layers per global layer; d_head=256; GeGLU; tied embeddings with
sqrt(d) scaling.  The sliding-window layers are a 1-D sequence stencil and
use the halo-style masking path (DESIGN.md §6); the 1-in-6 global layers
keep the arch quadratic => long_500k is skipped per the assignment rule.
"""

from repro.models.transformer import ArchConfig, LayerSpec

_PERIOD = tuple(
    [LayerSpec(kind="attn", window=1024)] * 5 + [LayerSpec(kind="attn")]
)

CONFIG = ArchConfig(
    name="gemma3_12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab=262144,
    period=_PERIOD,
    rope_theta=1e6,
    tie_embeddings=True,
    norm="rmsnorm",
    act="geglu",
    scale_embed=True,
)

SMOKE = ArchConfig(
    name="gemma3_12b_smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=tuple([LayerSpec(kind="attn", window=8)] * 5 + [LayerSpec(kind="attn")]),
    tie_embeddings=True,
    norm="rmsnorm",
    act="geglu",
    scale_embed=True,
    moe_group_size=16,
)
