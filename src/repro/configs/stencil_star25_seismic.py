"""Seismic-RTM stencil cells: the 25-point star workload.

Models the follow-on work the paper's §VII points toward: Jacquelin,
Araya-Polo & Meng, *Massively scalable stencil algorithm* (the 25-point
star — 8th-order finite differences, radius 4 per axis — that dominates
seismic reverse-time migration), run through this repo's BiCGStab stack as
an implicit-timestep solve (``stencil.high_order_star``).

The meshes mirror the scaling ladder of that paper's experiments at sizes
this repo's dry-run machinery can lower: a smoke cell, a single-chip-class
volume, and the full RTM-class volume (1008^2 x 352, the "n1008" grid
family), all Z-pencil friendly.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StencilFamilyCell:
    """One named stencil-family workload.

    A cell pins the full solve configuration: shape x stencil x precision
    plus the solver-stack choices of the operator/solver/precond layers
    (``launch.solve --solver/--backend/--precond``).
    """

    name: str
    mesh_shape: tuple[int, int, int]     # problem mesh (X, Y, Z)
    stencil: str                         # key into repro.core.stencil.SPECS
    policy: str = "bf16_mixed"
    problem: str = "seismic"             # launch.solve --problem value
    solver: str = "bicgstab"             # key into core.solvers.SOLVERS
    backend: str = "spmd"                # key into core.operator.BACKENDS
    precond: str = "none"                # core.precond.PRECONDS
    cheb_degree: int = 3                 # when precond == "chebyshev"
    schedule: str = "overlap"            # core.comm.SCHEDULES
    autotune: bool = False               # launch.solve --autotune: sweep the
    #                                      pallas kernel cell on first run,
    #                                      then serve from the tuning cache
    nrhs: int = 1                        # right-hand sides per block solve
    #                                      (launch.solve --nrhs): >1 batches
    #                                      the whole Krylov iteration — halo
    #                                      slabs of all RHS per ppermute, one
    #                                      AllReduce of [k, B] per sync point


SEISMIC_CELLS = {
    "rtm_smoke": StencilFamilyCell("rtm_smoke", (24, 24, 16), "star25",
                                   policy="f32"),
    "rtm_chip": StencilFamilyCell("rtm_chip", (96, 96, 352), "star25"),
    "rtm_n1008": StencilFamilyCell("rtm_n1008", (1008, 1008, 352), "star25"),
    # the preconditioned implicit-timestep variant: same operator, the
    # Chebyshev right-precondition cuts the AllReduce-bearing outer
    # iterations at the cost of local-only polynomial SpMVs
    "rtm_chip_cheb": StencilFamilyCell("rtm_chip_cheb", (96, 96, 352),
                                       "star25", precond="chebyshev"),
    # latency-lean variant for the large fabric: deep halos overlapped
    # under the wide star's interior, one AllReduce per iteration
    "rtm_n1008_pipelined": StencilFamilyCell(
        "rtm_n1008_pipelined", (1008, 1008, 352), "star25",
        solver="pipelined_bicgstab", schedule="overlap"),
    # the autotuned Pallas-backend variant: block shapes + ring-epilogue
    # choice come from the persistent tuning cache (swept on first run)
    "rtm_chip_tuned": StencilFamilyCell("rtm_chip_tuned", (96, 96, 352),
                                        "star25", backend="pallas",
                                        autotune=True),
}


#: Batched (many-RHS) workload cells, kept out of SEISMIC_CELLS: they are
#: not star25 workloads (the ops-table assertions over SEISMIC_CELLS assume
#: the seismic stencil) but the batched-solve benchmark's configuration
#: surface.  ``batched_poisson`` is the cell ``benchmarks/batched_solve.py``
#: sweeps over B.
BATCHED_CELLS = {
    "batched_poisson": StencilFamilyCell(
        "batched_poisson", (24, 24, 16), "star7", policy="f32",
        problem="poisson", solver="pipelined_bicgstab", schedule="overlap",
        nrhs=8),
}


def ops_per_meshpoint_star25() -> dict:
    """Per-iteration per-meshpoint counts, Table-I style, for star25.

    The SpMV term scales with the 24 off-diagonals (48 ops/SpMV); the dot
    and AXPY terms are shape-independent (8 + 12, as in the paper).
    """
    return {
        "matvec_hp_add": 48, "matvec_hp_mul": 48,
        "dot_hp_mul": 4, "dot_sp_add": 4,
        "axpy_hp_add": 6, "axpy_hp_mul": 6,
        "total": 116,
    }
