"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=151936; every layer
is MoE with 4 always-on shared experts (total shared ff = 5632) gated by a
sigmoid coefficient; QKV bias (Qwen family); d_head=128.
60 experts do not divide the 16-way model axis, so expert weights shard
tensor-parallel on the expert-ff dimension (DESIGN.md §6).
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen2_moe_a2_7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=5632,             # shared-expert aggregate (4 x 1408)
    vocab=151936,
    period=(LayerSpec(kind="attn", moe=True),),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_ff_expert=1408,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
)

SMOKE = ArchConfig(
    name="qwen2_moe_a2_7b_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=64,
    vocab=512,
    period=(LayerSpec(kind="attn", moe=True),),
    n_experts=8,
    top_k=4,
    n_shared_experts=2,
    d_ff_expert=32,
    qkv_bias=True,
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    moe_group_size=16,
)
