"""The paper's own problem configurations (stencil BiCGStab cells).

``cs1_paper`` is the measured configuration of §V: a 600 x 595 x 1536 mesh
(padded to 608 x 608 so the 16 x 16 chip fabric divides it; the CS-1 ran
602 x 595 tiles and also padded implicitly by mapping one pencil per core).
``joule_600`` / ``joule_370`` are the strong-scaling comparison meshes of
Figs. 7-8.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StencilCell:
    name: str
    mesh_shape: tuple[int, int, int]      # padded problem mesh (X, Y, Z)
    true_shape: tuple[int, int, int]      # the paper's unpadded mesh
    policy: str = "bf16_mixed"            # paper: fp16 + f32 reductions
    kind: str = "nonsymmetric"            # problem generator


STENCIL_CELLS = {
    "cs1_paper": StencilCell("cs1_paper", (608, 608, 1536), (600, 595, 1536)),
    "joule_600": StencilCell("joule_600", (608, 608, 608), (600, 600, 600)),
    "joule_370": StencilCell("joule_370", (384, 384, 370), (370, 370, 370)),
    "smoke": StencilCell("smoke", (16, 16, 8), (16, 16, 8), policy="f32"),
}


def ops_per_meshpoint() -> dict:
    """Paper Table I (mixed column): per iteration per meshpoint."""
    return {
        "matvec_hp_add": 12, "matvec_hp_mul": 12,
        "dot_hp_mul": 4, "dot_sp_add": 4,
        "axpy_hp_add": 6, "axpy_hp_mul": 6,
        "total": 44,
    }
