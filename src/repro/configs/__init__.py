"""Architecture registry: the 10 assigned configs + the paper's own stencil
problem, each with a reduced smoke twin (same family, tiny dims).

``get_config(name)`` returns the exact published configuration;
``get_smoke(name)`` a CPU-runnable reduction that preserves the layer
pattern (period), GQA ratio, MoE routing, and frontend stubs.
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "paligemma_3b",
    "stablelm_12b",
    "gemma3_12b",
    "qwen2_1_5b",
    "deepseek_7b",
    "rwkv6_7b",
    "qwen2_moe_a2_7b",
    "grok_1_314b",
    "whisper_large_v3",
    "jamba_v0_1_52b",
]

# CLI-friendly aliases (the assignment sheet's ids)
ALIASES = {
    "paligemma-3b": "paligemma_3b",
    "stablelm-12b": "stablelm_12b",
    "gemma3-12b": "gemma3_12b",
    "qwen2-1.5b": "qwen2_1_5b",
    "deepseek-7b": "deepseek_7b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "grok-1-314b": "grok_1_314b",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
