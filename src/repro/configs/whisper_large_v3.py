"""whisper-large-v3 [audio] — encoder-decoder [arXiv:2212.04356].

32+32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.  The conv frontend is
a STUB per the assignment: ``input_specs`` provides precomputed frame
embeddings to the encoder.  Decoder layers: causal self-attention +
cross-attention to the (frozen at decode: 1500 frames) encoder output.
LayerNorm + plain GELU MLP; RoPE substitutes the original learned/sinusoidal
positions (documented deviation, DESIGN.md §11).  20 heads do not divide the
16-way model axis => head projections fall back to d_head sharding.
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="whisper_large_v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab=51866,
    period=(LayerSpec(kind="attn", cross=True),),
    enc_dec=True,
    n_enc_layers=32,
    frontend="audio",
    enc_len_decode=1500,
    rope_theta=1e4,
    tie_embeddings=True,
    norm="layernorm",
    act="gelu",
)

SMOKE = ArchConfig(
    name="whisper_large_v3_smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="attn", cross=True),),
    enc_dec=True,
    n_enc_layers=2,
    frontend="audio",
    enc_len_decode=8,
    tie_embeddings=True,
    norm="layernorm",
    act="gelu",
    moe_group_size=16,
)
