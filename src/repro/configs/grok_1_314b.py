"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) per-expert d_ff=32768 vocab=131072;
d_head=128; attention-logit softcap 30; GeGLU experts; every layer MoE.
The largest assigned cell (~314B params): expert weights are TP-sharded on
the ff dimension (8 experts < 16-way model axis), params+optimizer live
sharded (~1.2 GB/chip bf16 on 512 chips).
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="grok_1_314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,
    vocab=131072,
    period=(LayerSpec(kind="attn", moe=True),),
    n_experts=8,
    top_k=2,
    d_ff_expert=32768,
    attn_softcap=30.0,
    rope_theta=1e4,
    tie_embeddings=False,
    norm="rmsnorm",
    act="geglu",
    scale_embed=True,
    # FSDP-style: 628 GB of bf16 weights cannot live 16-way sharded
    # (39 GB/chip); spread the big dims over the data axes too.  GSPMD
    # all-gathers weights per layer — the standard 300B-class trade.
    rules=(
        ("expert_ff", ("model", "data")),
        ("ff", ("model", "data")),
        ("vocab", ("model", "data")),
        ("heads", ("model", "data")),
        # dispatch/combine buffers are the next footprint driver at 6144-d:
        # spread MoE token groups over the model axis too (weights are
        # FSDP-gathered per layer regardless)
        ("moe_groups", ("pod", "data", "model")),
    ),
)

SMOKE = ArchConfig(
    name="grok_1_314b_smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="attn", moe=True),),
    n_experts=8,
    top_k=2,
    d_ff_expert=64,
    attn_softcap=30.0,
    tie_embeddings=False,
    norm="rmsnorm",
    act="geglu",
    scale_embed=True,
    moe_group_size=16,
)
