"""paligemma-3b [vlm] — SigLIP + Gemma backbone [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.  The vision frontend
is a stub per the assignment: ``input_specs`` supplies precomputed patch
embeddings (256 tokens, SigLIP-so400m/14 @ 224px); the backbone applies the
PaliGemma prefix-LM mask (bidirectional over image+prefix, causal after).
d_head=256 (Gemma family), GeGLU MLP, tied embeddings, sqrt(d) embed scale.
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="paligemma_3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    period=(LayerSpec(kind="attn"),),
    rope_theta=1e4,
    frontend="vlm",
    n_frontend_tokens=256,
    tie_embeddings=True,
    norm="rmsnorm",
    act="geglu",
    scale_embed=True,
)

SMOKE = ArchConfig(
    name="paligemma_3b_smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="attn"),),
    frontend="vlm",
    n_frontend_tokens=4,
    tie_embeddings=True,
    norm="rmsnorm",
    act="geglu",
    scale_embed=True,
    moe_group_size=16,
)
