"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-12b].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.  LayerNorm,
SwiGLU, untied head, d_head = 5120/32 = 160.
"""

from repro.models.transformer import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="stablelm_12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=160,
    d_ff=13824,
    vocab=100352,
    period=(LayerSpec(kind="attn"),),
    rope_theta=1e4,
    tie_embeddings=False,
    norm="layernorm",
    act="swiglu",
)

SMOKE = ArchConfig(
    name="stablelm_12b_smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(kind="attn"),),
    tie_embeddings=False,
    norm="layernorm",
    act="swiglu",
    moe_group_size=16,
)
