"""Stencil-solver driver: the paper's experiment at CPU scale, for the
whole stencil family and the full solver x backend x preconditioner matrix.

    PYTHONPATH=src python -m repro.launch.solve --mesh 48 48 32 --policy bf16_mixed
    PYTHONPATH=src python -m repro.launch.solve --stencil star25 --mesh 24 24 16
    PYTHONPATH=src python -m repro.launch.solve --solver cg --problem poisson
    PYTHONPATH=src python -m repro.launch.solve --precond chebyshev --problem poisson
    PYTHONPATH=src python -m repro.launch.solve --backend pallas --mesh 16 16 8
    PYTHONPATH=src python -m repro.launch.solve --solver pipelined_bicgstab --schedule overlap
    PYTHONPATH=src python -m repro.launch.solve --backend pallas --autotune --mesh 16 16 8

Builds a diagonally-dominant system with the requested stencil shape
(``star7`` is the paper's 7-point MFIX class; ``star25`` the high-order
seismic shape of Jacquelin et al.; ``box27`` the full-neighborhood cube),
solves it with the selected Krylov solver on the available device fabric —
through the SPMD halo path or the Pallas fused-kernel backend, optionally
right-preconditioned — and reports iterations / residuals / timings, with
the iterative-refinement option for f32-grade accuracy from a 16-bit solve.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bicgstab, precision, stencil
from repro.core.comm import SCHEDULES
from repro.core.operator import BACKENDS
from repro.core.precond import PRECONDS, PrecondConfig
from repro.core.solvers import SOLVERS
from repro.launch.mesh import make_mesh_for_devices


def build_problem(args, spec: stencil.StencilSpec):
    """Coefficients for the requested (problem, spec) pair."""
    shape = tuple(args.mesh)
    key = jax.random.PRNGKey(0)
    problem = args.problem
    if problem is None:  # shape-appropriate default
        if args.solver in ("cg", "pipelined_cg"):
            problem = "poisson"      # CG wants a symmetric operator
        elif spec == stencil.STAR7:
            problem = "convdiff"
        elif spec.pattern == "star":
            problem = "seismic"
        else:
            problem = "random"
    if problem == "random":
        return problem, stencil.random_nonsymmetric(key, shape, spec=spec)
    if problem == "poisson":
        return problem, stencil.poisson(shape, spec=spec)
    if problem == "heterogeneous":
        return problem, stencil.heterogeneous_poisson(key, shape, spec=spec)
    if problem == "seismic":
        if spec.pattern != "star":
            raise SystemExit("--problem seismic needs a star stencil")
        return problem, stencil.high_order_star(shape, spec.radius)
    if problem == "convdiff":
        if spec != stencil.STAR7:
            raise SystemExit("--problem convdiff is the 7-point MFIX class; "
                             "use seismic/random/poisson for other stencils")
        return problem, stencil.convection_diffusion(shape)
    raise SystemExit(f"unknown problem {problem!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, nargs=3, default=[48, 48, 32],
                    metavar=("X", "Y", "Z"))
    ap.add_argument("--stencil", default="star7", choices=sorted(stencil.SPECS),
                    help="stencil shape: star7 (paper), star13, star25 "
                         "(seismic RTM), box27")
    ap.add_argument("--solver", default="bicgstab", choices=sorted(SOLVERS),
                    help="Krylov solver (bicgstab: the paper's; cg: symmetric; "
                         "pipelined_*: single-reduction variants, 1 fused "
                         "AllReduce/iter)")
    ap.add_argument("--backend", default="spmd", choices=sorted(BACKENDS),
                    help="SpMV backend: spmd (halo local_apply), pallas "
                         "(fused kernels + 3 AllReduces/iter), reference")
    ap.add_argument("--schedule", default="overlap", choices=sorted(SCHEDULES),
                    help="communication schedule: overlap hides the halo "
                         "ppermutes under the interior apply (bit-identical "
                         "to blocking)")
    ap.add_argument("--precond", default="none", choices=sorted(PRECONDS),
                    help="right preconditioner (local — the collective "
                         "schedule is unchanged)")
    ap.add_argument("--cheb-degree", type=int, default=3,
                    help="Chebyshev polynomial degree (extra local SpMVs "
                         "per apply, no extra AllReduces)")
    ap.add_argument("--policy", default="bf16_mixed",
                    choices=sorted(precision.POLICIES))
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--problem", default=None,
                    choices=["convdiff", "random", "poisson", "heterogeneous",
                             "seismic"],
                    help="default: convdiff for star7, seismic for deeper "
                         "stars, random for box, poisson for --solver cg; "
                         "heterogeneous is the raw variable-diagonal case "
                         "where --precond jacobi does real work")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep the Pallas kernel tuning space for this "
                         "cell if the tuning cache has no entry, then "
                         "solve with the tuned shapes (cache path: "
                         "REPRO_TUNING_CACHE or results/tuning_cache.json)")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="number of right-hand sides solved as one block "
                         "(batched) Krylov solve: halo slabs of all RHS "
                         "ride each ppermute and every sync point is one "
                         "AllReduce of stacked [k, B] scalars")
    ap.add_argument("--refine", action="store_true",
                    help="iterative refinement to f32 accuracy")
    ap.add_argument("--paper-separate-reductions", action="store_true",
                    help="paper-faithful: one AllReduce per dot product")
    ap.add_argument("--obs", action="store_true",
                    help="observability: spans + metrics + a run bundle "
                         "results/runs/<run_id>/{manifest.json,events.jsonl,"
                         "trace.json} (trace.json loads in Perfetto)")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the solve in jax.profiler.trace into "
                         "<run_dir>/jax_profile (implies --obs)")
    ap.add_argument("--run-dir", default=None,
                    help="bundle directory override (implies --obs; "
                         "default results/runs/<run_id>)")
    args = ap.parse_args()

    args.obs = args.obs or args.profile or args.run_dir is not None
    run_ctx = None
    if args.obs:
        from repro.obs import manifest as obs_manifest
        from repro.obs import trace as obs_trace

        obs_trace.enable(sync=True)
        run_ctx = obs_manifest.start_run(
            "solve", config=vars(args), run_dir=args.run_dir,
            profile=args.profile)
    try:
        _solve(args)
    finally:
        if run_ctx is not None:
            from repro.obs import manifest as obs_manifest

            obs_manifest.finish_run(run_ctx)
            print(f"run bundle: {run_ctx.run_dir}")


def _solve(args) -> None:
    if args.policy == "f64":
        # get_policy("f64") refuses to hand out a policy that would silently
        # degrade; the CLI owns process startup, so it can just enable x64.
        jax.config.update("jax_enable_x64", True)
    shape = tuple(args.mesh)
    spec = stencil.get_spec(args.stencil)
    pol = precision.get_policy(args.policy)
    mesh = make_mesh_for_devices()
    problem, cf = build_problem(args, spec)
    print(f"problem {problem}/{spec.name} (radius {spec.radius}, "
          f"{spec.n_points} points) {shape} on fabric {dict(mesh.shape)} "
          f"solver={args.solver} backend={args.backend} "
          f"schedule={args.schedule} precond={args.precond} policy={pol.name}")

    if args.autotune:
        # tune the per-shard kernel cell the pallas backend will look up:
        # the local block shape under this fabric, in the storage dtype
        from repro.core import tuning
        from repro.core.halo import FabricAxes

        fabric = FabricAxes.from_mesh(mesh)
        local = (shape[0] // fabric.nx, shape[1] // fabric.ny,
                 shape[2] // fabric.nz)
        rec = tuning.ensure_tuned(spec, pol.storage, local)
        hit = "cache hit" if rec["cache_hit"] else "swept"
        print(f"autotune[{rec['key']}]: {hit}, config={rec['config']}"
              + ("" if rec["cache_hit"] else
                 f", speedup vs default {rec['speedup_vs_default']:.2f}x"))

    if args.nrhs < 1:
        raise SystemExit("--nrhs must be >= 1")
    # nrhs == 1 stays on the unbatched path (bitwise-identical output)
    xshape = (args.nrhs,) + shape if args.nrhs > 1 else shape
    x_true = jax.random.normal(jax.random.PRNGKey(1), xshape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)

    if args.refine:
        if args.nrhs > 1:
            raise SystemExit("--refine is single-RHS; drop --nrhs")
        if (args.solver, args.backend, args.precond) != ("bicgstab", "spmd", "none"):
            raise SystemExit(
                "--refine drives its own inner bicgstab/spmd solves and does "
                "not honor --solver/--backend/--precond; drop those flags")
        t0 = time.time()
        x, rels = bicgstab.solve_refined(cf, b, mesh=mesh, inner_policy=pol)
        dt = time.time() - t0
        print("refinement true-residual trajectory:",
              [f"{r:.2e}" for r in np.asarray(rels)])
        err = float(jnp.abs(x - x_true).max())
        print(f"max err vs manufactured solution: {err:.3e}  ({dt:.2f}s)")
        return

    from repro.core.solvers.common import emit_solve_metrics
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    pconf = PrecondConfig(name=args.precond, degree=args.cheb_degree)
    solve_kwargs = dict(
        tol=args.tol, maxiter=args.maxiter, policy=pol, solver=args.solver,
        backend=args.backend, precond=pconf, schedule=args.schedule,
        fused_reductions=not args.paper_separate_reductions)
    labels = dict(solver=args.solver, backend=args.backend,
                  schedule=args.schedule, nrhs=args.nrhs, problem=problem,
                  policy=pol.name)
    bs = b.astype(pol.storage)
    t0 = time.time()
    with obs_trace.span("solve.krylov", **labels) as sp:
        res = bicgstab.solve_distributed(mesh, cf, bs, **solve_kwargs)
        sp.block(res.x)
    jax.block_until_ready(res.x)
    dt = time.time() - t0
    emit_solve_metrics(res, wall_s=dt, **labels)
    if obs_trace.is_enabled():
        # lowered-HLO collective counts for this exact solve (lower only,
        # no second compile) — the events.jsonl ground truth tests check
        with obs_trace.span("solve.lower_hlo"):
            text = jax.jit(
                lambda c, v: bicgstab.solve_distributed(
                    mesh, c, v, **solve_kwargs)).lower(cf, bs).as_text()
        counts = obs_metrics.record_collectives(text, **labels)
        print(f"collectives (whole solve HLO): "
              f"allreduce={counts['allreduce_total']} "
              f"ppermute={counts['ppermute_total']}")
    # achieved-vs-peak roofline fraction, the paper's accounting (§VII:
    # ~1/3 of peak on the CS-1; a CPU smoke run reports a tiny fraction)
    iters_total = int(np.asarray(res.iterations).sum())
    from repro.core import perfmodel

    achieved = (perfmodel.FLOPS_PER_PT * float(np.prod(shape))
                * iters_total / max(dt, 1e-12))
    frac = obs_metrics.roofline_fraction(achieved)
    print(f"roofline: {achieved / 1e9:.2f} GFLOP/s achieved, "
          f"{frac:.2e} of wafer peak")
    bb = np.asarray(b, np.float64)
    r = bb - np.asarray(
        stencil.apply_ref(cf.astype(jnp.float32), res.x.astype(jnp.float32)))
    if args.nrhs > 1:
        axes = tuple(range(1, bb.ndim))
        true_rel = (np.sqrt((r ** 2).sum(axes))
                    / np.sqrt((bb ** 2).sum(axes)))
        iters = np.asarray(res.iterations)
        print(f"per-RHS iterations: {iters.tolist()}")
        print(f"per-RHS converged:  {np.asarray(res.converged).tolist()}")
        print("recurrence rel-residuals:",
              [f"{v:.3e}" for v in np.asarray(res.rel_residual)])
        print("true rel-residuals (f32 check):",
              [f"{v:.3e}" for v in true_rel])
        print(f"wall time: {dt:.2f}s for {args.nrhs} RHS "
              f"({dt / max(int(iters.max()), 1) * 1e3:.1f} ms/iter on CPU)")
        return
    true_rel = np.linalg.norm(r) / np.linalg.norm(bb)
    print(f"iterations: {int(res.iterations)}  converged: {bool(res.converged)}")
    print(f"recurrence rel-residual: {float(res.rel_residual):.3e}")
    print(f"true rel-residual (f32 check): {true_rel:.3e}")
    print(f"wall time: {dt:.2f}s "
          f"({dt / max(int(res.iterations), 1) * 1e3:.1f} ms/iter on CPU)")


if __name__ == "__main__":
    main()
