"""Stencil-solver driver: the paper's experiment at CPU scale.

    PYTHONPATH=src python -m repro.launch.solve --mesh 48 48 32 --policy bf16_mixed

Builds a diagonally-dominant nonsymmetric 7-point system (the class MFIX
produces), solves it with distributed BiCGStab on the available device
fabric, and reports iterations / residuals / timings, with the iterative-
refinement option for f32-grade accuracy from a 16-bit solve.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bicgstab, precision, stencil
from repro.launch.mesh import make_mesh_for_devices


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", type=int, nargs=3, default=[48, 48, 32],
                    metavar=("X", "Y", "Z"))
    ap.add_argument("--policy", default="bf16_mixed",
                    choices=sorted(precision.POLICIES))
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--problem", default="convdiff",
                    choices=["convdiff", "random", "poisson"])
    ap.add_argument("--refine", action="store_true",
                    help="iterative refinement to f32 accuracy")
    ap.add_argument("--paper-separate-reductions", action="store_true",
                    help="paper-faithful: one AllReduce per dot product")
    args = ap.parse_args()

    shape = tuple(args.mesh)
    pol = precision.get_policy(args.policy)
    mesh = make_mesh_for_devices()
    print(f"problem {shape} on fabric {dict(mesh.shape)} policy={pol.name}")

    key = jax.random.PRNGKey(0)
    if args.problem == "random":
        cf = stencil.random_nonsymmetric(key, shape)
    elif args.problem == "poisson":
        cf = stencil.poisson(shape)
    else:
        cf = stencil.convection_diffusion(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)

    if args.refine:
        t0 = time.time()
        x, rels = bicgstab.solve_refined(cf, b, mesh=mesh, inner_policy=pol)
        dt = time.time() - t0
        print("refinement true-residual trajectory:",
              [f"{r:.2e}" for r in np.asarray(rels)])
        err = float(jnp.abs(x - x_true).max())
        print(f"max err vs manufactured solution: {err:.3e}  ({dt:.2f}s)")
        return

    t0 = time.time()
    res = bicgstab.solve_distributed(
        mesh, cf, b.astype(pol.storage), tol=args.tol, maxiter=args.maxiter,
        policy=pol, fused_reductions=not args.paper_separate_reductions)
    jax.block_until_ready(res.x)
    dt = time.time() - t0
    r = np.asarray(b, np.float64) - np.asarray(
        stencil.apply_ref(cf.astype(jnp.float32), res.x.astype(jnp.float32)))
    true_rel = np.linalg.norm(r) / np.linalg.norm(np.asarray(b, np.float64))
    print(f"iterations: {int(res.iterations)}  converged: {bool(res.converged)}")
    print(f"recurrence rel-residual: {float(res.rel_residual):.3e}")
    print(f"true rel-residual (f32 check): {true_rel:.3e}")
    print(f"wall time: {dt:.2f}s "
          f"({dt / max(int(res.iterations), 1) * 1e3:.1f} ms/iter on CPU)")


if __name__ == "__main__":
    main()
