"""CFD application driver: SIMPLE through the pluggable solver stack.

    PYTHONPATH=src python -m repro.launch.cfd --scenario cavity --backend spmd --precond jacobi
    PYTHONPATH=src python -m repro.launch.cfd --scenario cavity --raw-coeffs --precond jacobi
    PYTHONPATH=src python -m repro.launch.cfd --scenario channel --dt 0.05 --steps 40 \\
        --checkpoint-dir /tmp/cfd_ckpt
    PYTHONPATH=src python -m repro.launch.cfd --p-solver pipelined_bicgstab --schedule overlap

Steady mode runs the lid-driven cavity (or channel) SIMPLE loop to
convergence and, for the Re=100 cavity, verifies the Ghia et al. (1982)
centerline structure.  Transient mode (``--dt --steps``) marches implicit-
Euler time steps with under-relaxed outer loops per step; with
``--checkpoint-dir`` the run is fault-tolerant and resumable (restart from
the latest checkpoint is automatic and bit-deterministic).

``--solver/--backend/--precond/--policy`` select the same registry entries
as ``launch/solve.py`` — the application consumes the stack, it does not
reimplement it.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.apps.cfd import (
    CFDConfig, SolverOptions, TransientConfig, centerline_u, run_transient,
    solve_steady, to_staggered,
)
from repro.core import precision
from repro.core.comm import SCHEDULES
from repro.core.precond import PRECONDS
from repro.core.solvers import SOLVERS
from repro.launch.mesh import make_mesh_for_devices


def ghia_check(u_stag) -> tuple[bool, str]:
    """Qualitative Ghia et al. Re=100 centerline structure (coarse-grid band,
    same acceptance band as tests/test_cfd.py)."""
    cl = np.asarray(centerline_u(u_stag))
    checks = [
        ("return-flow strength -0.30 < min < -0.10", -0.30 < cl.min() < -0.10),
        ("return flow near mid-height", 0.25 < cl.argmin() / len(cl) < 0.75),
        ("lid-adjacent cells dragged (u > 0.4)", cl[-1] > 0.4),
        ("near-stationary bottom (|u| < 0.1)", abs(cl[0]) < 0.1),
    ]
    ok = all(passed for _, passed in checks)
    lines = [f"  [{'ok' if passed else 'FAIL'}] {name}" for name, passed in checks]
    return ok, "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="cavity", choices=["cavity", "channel"])
    ap.add_argument("--n", type=int, default=32, help="cells per side")
    ap.add_argument("--re", type=float, default=100.0, help="Reynolds number")
    ap.add_argument("--u-in", type=float, default=1.0, help="channel inflow velocity")
    ap.add_argument("--solver", default="bicgstab", choices=sorted(SOLVERS))
    ap.add_argument("--p-solver", default=None, choices=sorted(SOLVERS),
                    help="route the pressure-correction solve through a "
                         "different solver (e.g. pipelined_bicgstab: 1 "
                         "AllReduce per inner iteration); default: --solver")
    ap.add_argument("--backend", default="spmd",
                    choices=["reference", "spmd"],
                    help="operator backend for the inner solves (spmd runs "
                         "the whole SIMPLE iteration inside shard_map)")
    ap.add_argument("--schedule", default="overlap", choices=sorted(SCHEDULES),
                    help="halo communication schedule for the inner-solve "
                         "SpMVs (overlap is bit-identical to blocking)")
    ap.add_argument("--precond", default="none", choices=sorted(PRECONDS))
    ap.add_argument("--cheb-degree", type=int, default=3)
    ap.add_argument("--policy", default="f32", choices=sorted(precision.POLICIES))
    ap.add_argument("--raw-coeffs", action="store_true",
                    help="hand the solver the raw aP-diagonal rows instead of "
                         "pre-normalized unit-diagonal ones (makes --precond "
                         "jacobi do real registry work)")
    ap.add_argument("--outer", type=int, default=400,
                    help="steady outer-iteration cap (or per-step cap, see --dt)")
    ap.add_argument("--tol", type=float, default=5e-6, help="continuity tolerance")
    ap.add_argument("--dt", type=float, default=None,
                    help="time-step size: switches to the transient driver")
    ap.add_argument("--steps", type=int, default=50, help="transient time steps")
    ap.add_argument("--outers-per-step", type=int, default=20)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="transient only: checkpointed fault-tolerant march "
                         "(resumes automatically from the latest checkpoint)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the Ghia centerline acceptance check")
    ap.add_argument("--obs", action="store_true",
                    help="observability: spans + metrics + a run bundle "
                         "results/runs/<run_id>/ (see docs/observability.md)")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the run in jax.profiler.trace (implies --obs)")
    ap.add_argument("--run-dir", default=None,
                    help="bundle directory override (implies --obs)")
    args = ap.parse_args()

    args.obs = args.obs or args.profile or args.run_dir is not None
    run_ctx = None
    if args.obs:
        from repro.obs import manifest as obs_manifest
        from repro.obs import trace as obs_trace

        obs_trace.enable(sync=True)
        run_ctx = obs_manifest.start_run(
            "cfd", config=vars(args), run_dir=args.run_dir,
            profile=args.profile)
    try:
        _cfd(args)
    finally:
        if run_ctx is not None:
            from repro.obs import manifest as obs_manifest

            obs_manifest.finish_run(run_ctx)
            print(f"run bundle: {run_ctx.run_dir}")


def _cfd(args) -> None:
    if args.policy == "f64":
        jax.config.update("jax_enable_x64", True)
    pol = precision.get_policy(args.policy)
    cfg = CFDConfig(n=args.n, reynolds=args.re, scenario=args.scenario,
                    u_in=args.u_in, outer_iters=args.outer, tol=args.tol,
                    policy=pol)
    opts = SolverOptions(solver=args.solver, backend=args.backend,
                         precond=args.precond, cheb_degree=args.cheb_degree,
                         normalize=not args.raw_coeffs,
                         schedule=args.schedule, p_solver=args.p_solver)
    mesh = make_mesh_for_devices() if args.backend != "reference" else None
    fab = dict(mesh.shape) if mesh is not None else {"local": 1}
    print(f"SIMPLE {args.scenario} n={args.n} Re={args.re:g} on fabric {fab} "
          f"solver={args.solver} p_solver={opts.pressure_solver} "
          f"backend={args.backend} schedule={args.schedule} "
          f"precond={args.precond} policy={pol.name} "
          f"rows={'raw' if args.raw_coeffs else 'unit-diagonal'}")
    if args.precond == "jacobi" and not args.raw_coeffs:
        print("note: unit-diagonal rows make jacobi the identity (the paper's "
              "pre-normalization); use --raw-coeffs for real Jacobi work")

    t0 = time.time()
    if args.dt is not None:
        tcfg = TransientConfig(dt=args.dt, n_steps=args.steps,
                               outers_per_step=args.outers_per_step)
        (u, v, p), metrics = run_transient(cfg, tcfg, opts, mesh,
                                           checkpoint_dir=args.checkpoint_dir)
        dt_wall = time.time() - t0
        last = metrics[-1] if metrics else {}
        print(f"{len(metrics)} steps of dt={args.dt:g} in {dt_wall:.1f}s "
              f"({dt_wall / max(len(metrics), 1) * 1e3:.0f} ms/step); "
              f"final continuity residual {last.get('continuity', float('nan')):.3e}")
    else:
        u, v, p, hist = solve_steady(cfg, opts, mesh)
        dt_wall = time.time() - t0
        print(f"outer iterations: {len(hist)} (continuity {hist[0]:.2e} -> "
              f"{hist[-1]:.2e}) in {dt_wall:.1f}s")
        if hist[-1] >= cfg.tol:
            print("WARNING: did not reach --tol within --outer iterations")

    u_stag, _v_stag = to_staggered(u, v)
    if args.scenario == "cavity":
        cl = np.asarray(centerline_u(u_stag))
        print(f"centerline u: min={cl.min():.3f} (Ghia Re=100 fine-grid "
              f"reference ~ -0.21; first-order upwind on {args.n}^2 is diffusive)")
        if not args.no_check and args.dt is None and 90 <= args.re <= 110:
            ok, report = ghia_check(u_stag)
            print("Ghia Re=100 centerline check:")
            print(report)
            if not ok:
                raise SystemExit(1)
    else:
        h = 1.0 / args.n
        outflux = float(u[-1, :].sum() * h)
        mid = np.asarray(u[args.n // 2, :])
        print(f"channel: outlet flux {outflux:.4f} (inflow {args.u_in:g}), "
              f"mid-channel profile center/wall = "
              f"{mid[args.n // 2]:.3f}/{mid[0]:.3f}")


if __name__ == "__main__":
    main()
