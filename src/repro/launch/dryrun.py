import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get(
    "REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production mesh and extract the roofline terms.

This file MUST set XLA_FLAGS before any jax import (jax locks the device
count at first init), which is why the docstring sits below the os.environ
lines.  Do not import this module from tests — run it as a script:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_1_5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Per cell it records: memory_analysis (fits-per-chip proof), cost_analysis
(per-chip HLO flops/bytes), the collective schedule parsed from the compiled
HLO (op x shape x replica-group), and the three roofline terms of
EXPERIMENTS.md §Roofline.
"""

import argparse
import dataclasses
import json
import math
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.stencil_cs1 import STENCIL_CELLS
from repro.core import bicgstab, precision
from repro.core.halo import FabricAxes
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.transformer import ArchConfig


# TPU v5e hardware constants (assignment sheet)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

LM_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


# ---------------------------------------------------------------------------
# Collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}?,")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-chip collective traffic from the (per-device SPMD) compiled HLO.

    bytes_raw  = sum of output-shape bytes (the assignment's "operand sizes").
    bytes_link = ring-model bytes that actually cross a link per chip:
      all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
      collective-permute 1x.
    """
    ops = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done" in line:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        g = _group_size(line, n_devices)
        if kind == "all-reduce":
            factor = 2 * (g - 1) / max(g, 1)
        elif kind == "collective-permute":
            factor = 1.0
        else:
            factor = (g - 1) / max(g, 1)
        ops.append({"op": kind, "bytes": nbytes, "group": g,
                    "link_bytes": nbytes * factor})
    agg: dict = {}
    for o in ops:
        a = agg.setdefault(o["op"], {"count": 0, "bytes": 0, "link_bytes": 0.0})
        a["count"] += 1
        a["bytes"] += o["bytes"]
        a["link_bytes"] += o["link_bytes"]
    return {
        "by_op": agg,
        "total_bytes": sum(o["bytes"] for o in ops),
        "total_link_bytes": sum(o["link_bytes"] for o in ops),
        "n_collectives": len(ops),
    }


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _mem_dict(mem) -> dict:
    return {k: getattr(mem, k) for k in (
        "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
        "alias_size_in_bytes", "generated_code_size_in_bytes")}


def analyze(compiled, mesh, *, model_flops: float | None = None,
            steps_per_unit: float = 1.0) -> dict:
    n_dev = math.prod(mesh.devices.shape)
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text(), n_dev)
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_acc / HBM_BW
    t_coll = coll["total_link_bytes"] / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
                   key=lambda kv: kv[1])[0]
    out = {
        "n_devices": n_dev,
        "per_chip_flops": flops,
        "per_chip_bytes": bytes_acc,
        "collectives": coll,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "t_bound_s": max(t_comp, t_mem, t_coll),
        "dominant": dominant,
        "memory_analysis": _mem_dict(compiled.memory_analysis()),
    }
    if model_flops is not None:
        hlo_global = flops * n_dev
        out["model_flops_global"] = model_flops
        out["useful_flops_ratio"] = model_flops / hlo_global if hlo_global else 0.0
        out["mfu_bound"] = (model_flops / n_dev / PEAK_FLOPS) / max(
            out["t_bound_s"], 1e-30) / steps_per_unit
    return out


def _compile_step(cfg: ArchConfig, shape, mesh):
    """Lower+compile the cell's step under the ambient mesh."""
    params = M.abstract_params(cfg, mesh)
    batch = M.input_specs(cfg, shape, mesh)
    from repro.compat import set_mesh
    with set_mesh(mesh):
        if shape.kind == "train":
            opt = M.abstract_opt_state(cfg, mesh)
            step = M.make_train_step(cfg)
            out_sh = M.out_shardings_for_train(cfg, mesh)
            lowered = jax.jit(step, donate_argnums=(0, 1),
                              out_shardings=out_sh).lower(params, opt, batch)
        elif shape.kind == "prefill":
            caches = M.abstract_caches(cfg, shape, mesh)
            step = M.make_prefill_step(cfg, shape)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(params, batch, caches)
        else:
            caches = M.abstract_caches(cfg, shape, mesh)
            step = M.make_serve_step(cfg)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(params, batch, caches)
        return lowered.compile()


def _cost_vector(compiled, mesh) -> dict:
    n_dev = math.prod(mesh.devices.shape)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # pre-0.4.30 jax: one dict per device
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text(), n_dev)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "coll_link_bytes": float(coll["total_link_bytes"]),
        "n_collectives": coll["n_collectives"],
    }


def _extrapolate(c1: dict, c2: dict, n_periods: int) -> dict:
    """total = probe1 + (P-1) * (probe2 - probe1): exact for a periodic stack."""
    out = {}
    for k in c1:
        out[k] = c1[k] + (n_periods - 1) * (c2[k] - c1[k])
    return out


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  cfg: ArchConfig | None = None, *, probes: bool = True) -> dict:
    cfg = cfg or get_config(arch)
    shape = M.SHAPES[shape_name]
    ok, reason = M.cell_is_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "kind": shape.kind}
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return rec

    from repro.models.param import rule_overrides
    with rule_overrides(dict(cfg.rules)):
        return _lower_lm_cell_inner(arch, shape_name, multi_pod, cfg, shape,
                                    rec, probes)


def _lower_lm_cell_inner(arch, shape_name, multi_pod, cfg, shape, rec, probes):
    mesh = make_production_mesh(multi_pod=multi_pod)

    # (A) full-depth scanned compile: the sharding/memory proof
    t0 = time.time()
    compiled = _compile_step(cfg, shape, mesh)
    rec["lower_compile_s"] = time.time() - t0
    rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
    rec["scan_cost_raw"] = _cost_vector(compiled, mesh)

    # (B) unrolled 1-/2-period cost probes: exact per-period extrapolation
    # (XLA cost analysis counts loop bodies once; see model.probe_config)
    n_dev = math.prod(mesh.devices.shape)
    has_rwkv = any(s.kind == "rwkv" for s in cfg.period)
    if probes and has_rwkv and shape.kind != "decode":
        # RWKV cost is affine in seq_len => bilinear (depth x T) probes keep
        # the chunk loop tiny enough to unroll exactly.
        Ta, Tb = 2 * cfg.rwkv_chunk, 4 * cfg.rwkv_chunk
        t0 = time.time()

        def cv(k, T):
            sh = dataclasses.replace(shape, seq_len=T)
            return _cost_vector(_compile_step(M.probe_config(cfg, k, T), sh, mesh), mesh)

        c1a, c2a, c1b, c2b = cv(1, Ta), cv(2, Ta), cv(1, Tb), cv(2, Tb)
        rec["probe_compile_s"] = time.time() - t0
        T = shape.seq_len
        cost = {}
        for key in c1a:
            b_a, b_b = c2a[key] - c1a[key], c2b[key] - c1b[key]
            a_a, a_b = c1a[key] - b_a, c1b[key] - b_b
            b_T = b_a + (b_b - b_a) * (T - Ta) / (Tb - Ta)
            a_T = a_a + (a_b - a_a) * (T - Ta) / (Tb - Ta)
            cost[key] = a_T + cfg.n_periods * b_T
        rec["probe_mode"] = "bilinear_depth_x_seq"
        rec["probe1_cost"], rec["probe2_cost"] = c1a, c2b
    elif probes:
        t0 = time.time()
        c1 = _cost_vector(_compile_step(M.probe_config(cfg, 1, shape.seq_len),
                                        shape, mesh), mesh)
        c2 = _cost_vector(_compile_step(M.probe_config(cfg, 2, shape.seq_len),
                                        shape, mesh), mesh)
        rec["probe_compile_s"] = time.time() - t0
        cost = _extrapolate(c1, c2, cfg.n_periods)
        rec["probe_mode"] = "depth"
        rec["probe1_cost"], rec["probe2_cost"] = c1, c2
    else:
        cost = rec["scan_cost_raw"]

    t_comp = cost["flops"] / PEAK_FLOPS
    t_mem = cost["bytes"] / HBM_BW
    t_coll = cost["coll_link_bytes"] / LINK_BW
    n = M.n_params(cfg)
    n_act = M.n_active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * n_act * tokens
    hlo_global = cost["flops"] * n_dev

    rec.update({
        "n_devices": n_dev,
        "per_chip_flops": cost["flops"],
        "per_chip_bytes": cost["bytes"],
        "coll_bytes": cost["coll_bytes"],
        "coll_link_bytes": cost["coll_link_bytes"],
        "n_collectives": cost["n_collectives"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "t_bound_s": max(t_comp, t_mem, t_coll),
        "dominant": max(("compute", t_comp), ("memory", t_mem),
                        ("collective", t_coll), key=lambda kv: kv[1])[0],
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "n_params": n,
        "n_active_params": n_act,
        "tokens_per_step": tokens,
    })
    from repro.launch.roofline_model import lm_cell_memory_estimate
    est = lm_cell_memory_estimate(cfg, shape, mesh)
    rec.update(est)
    rec["t_memory_est_s"] = est["est_hbm_traffic_bytes"] / HBM_BW
    rec["t_bound_est_s"] = max(t_comp, rec["t_memory_est_s"], t_coll)
    rec["dominant_est"] = max(
        ("compute", t_comp), ("memory", rec["t_memory_est_s"]),
        ("collective", t_coll), key=lambda kv: kv[1])[0]
    rec["roofline_fraction"] = (model_flops / n_dev / PEAK_FLOPS) / max(
        rec["t_bound_s"], 1e-30)
    rec["roofline_fraction_est"] = (model_flops / n_dev / PEAK_FLOPS) / max(
        rec["t_bound_est_s"], 1e-30)
    rec["status"] = "ok"
    return rec


def _compile_stencil(cell, mesh, policy, *, fused, overlap):
    fabric = FabricAxes.from_mesh(mesh)
    X, Y, Z = cell.mesh_shape
    spec = fabric.spec(3)
    sh = NamedSharding(mesh, spec)
    rep = NamedSharding(mesh, P())
    vec = jax.ShapeDtypeStruct((X, Y, Z), policy.storage, sharding=sh)
    scl = jax.ShapeDtypeStruct((), jnp.float32, sharding=rep)
    from repro.core.stencil import StencilCoeffs, DIAGS_3D
    cf = StencilCoeffs({n: vec for n in DIAGS_3D})
    it = bicgstab.make_iteration_fn(mesh, policy=policy, fused_reductions=fused,
                                    overlap_halo=overlap)
    lowered = jax.jit(it, donate_argnums=(1, 2, 3)).lower(cf, vec, vec, vec, vec, scl)
    return lowered.compile()


def lower_stencil_cell(cell_name: str, multi_pod: bool, *, fused: bool = True,
                       overlap: bool = True, policy_name: str | None = None) -> dict:
    """Stencil BiCGStab iteration roofline.

    Two compiles: the requested policy (usually bf16_mixed — proves the
    16-bit program partitions and fits) and an f32 twin used for FLOP
    counting.  On the CPU backend, bf16 math lowers through explicit
    converts that HloCostAnalysis counts as flops (a ~19x artifact absent
    on TPU, where bf16 is native); the f32 twin counts the same real
    arithmetic without converts (measured ratio vs the paper's 44
    ops/meshpoint: 1.11).  Bytes for the 16-bit policy are the f32 bytes
    scaled by the storage-width ratio — identical op schedule, half-width
    words — and halo collective-permute traffic scales the same way.
    """
    cell = STENCIL_CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = precision.get_policy(policy_name or cell.policy)
    X, Y, Z = cell.mesh_shape
    rec = {"arch": f"stencil_{cell_name}", "shape": "bicgstab_iter",
           "mesh": "2x16x16" if multi_pod else "16x16", "kind": "solver",
           "fused_reductions": fused, "overlap_halo": overlap,
           "policy": policy.name}
    n_dev = math.prod(mesh.devices.shape)

    t0 = time.time()
    compiled = _compile_stencil(cell, mesh, policy, fused=fused, overlap=overlap)
    rec["lower_compile_s"] = time.time() - t0
    rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
    rec["policy_cost_raw"] = _cost_vector(compiled, mesh)

    if policy.storage != jnp.float32:
        c32 = _compile_stencil(cell, mesh, precision.F32, fused=fused,
                               overlap=overlap)
        cost32 = _cost_vector(c32, mesh)
        ratio = jnp.dtype(policy.storage).itemsize / 4.0
        cost = {
            "flops": cost32["flops"],
            "bytes": cost32["bytes"] * ratio,
            "coll_bytes": cost32["coll_bytes"] * ratio,
            "coll_link_bytes": cost32["coll_link_bytes"] * ratio,
            "n_collectives": cost32["n_collectives"],
        }
        rec["f32_cost_raw"] = cost32
    else:
        cost = rec["policy_cost_raw"]

    npts = X * Y * Z
    model_flops = 44.0 * npts          # paper Table I: 44 ops/meshpoint/iter
    t_comp = cost["flops"] / PEAK_FLOPS
    t_mem = cost["bytes"] / HBM_BW
    t_coll = cost["coll_link_bytes"] / LINK_BW
    from repro.launch.roofline_model import stencil_cell_memory_estimate
    pods = 2 if multi_pod else 1
    est = stencil_cell_memory_estimate(
        cell.mesh_shape, (16, 16, pods),
        itemsize=jnp.dtype(policy.storage).itemsize)
    rec.update({
        "n_devices": n_dev,
        "per_chip_flops": cost["flops"],
        "per_chip_bytes": cost["bytes"],
        "coll_link_bytes": cost["coll_link_bytes"],
        "n_collectives": cost["n_collectives"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "t_memory_est_s": est["est_hbm_traffic_bytes"] / HBM_BW,
        "t_bound_s": max(t_comp, t_mem, t_coll),
        "dominant": max(("compute", t_comp), ("memory", t_mem),
                        ("collective", t_coll), key=lambda kv: kv[1])[0],
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / (cost["flops"] * n_dev),
        "meshpoints": npts,
        "paper_iter_us_cs1": 28.1 if cell_name == "cs1_paper" else None,
        **est,
    })
    rec["roofline_fraction"] = (model_flops / n_dev / PEAK_FLOPS) / max(
        rec["t_bound_s"], 1e-30)
    rec["status"] = "ok"
    return rec


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_cells(cells, out_dir: str) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for kind, name, shape, multi_pod in cells:
        tag = f"{name}__{shape}__{'pod2' if multi_pod else 'pod1'}"
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("status") in ("ok", "skipped"):
                print(f"[cached] {tag}: {rec.get('status')}")
                results.append(rec)
                continue
        print(f"[lower ] {tag} ...", flush=True)
        try:
            if kind == "lm":
                rec = lower_lm_cell(name, shape, multi_pod)
            else:
                rec = lower_stencil_cell(name, multi_pod)
        except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
            rec = {"arch": name, "shape": shape,
                   "mesh": "2x16x16" if multi_pod else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        status = rec.get("status")
        extra = ""
        if status == "ok":
            extra = (f" dominant={rec['dominant']}"
                     f" t_bound={rec['t_bound_s']:.3e}s"
                     f" compile={rec.get('lower_compile_s', 0):.0f}s")
        print(f"[done  ] {tag}: {status}{extra}", flush=True)
        results.append(rec)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="arch id or stencil cell (stencil:<name>)")
    ap.add_argument("--shape", help="shape name (LM archs)", default=None)
    ap.add_argument("--mesh", choices=["single", "pod", "both"], default="both")
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--stencil-only", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    pods = {"single": [False], "pod": [True], "both": [False, True]}[args.mesh]
    cells: list = []
    if args.all or args.stencil_only:
        if not args.stencil_only:
            for arch in ARCH_IDS:
                for shape in LM_SHAPES:
                    for mp in pods:
                        cells.append(("lm", arch, shape, mp))
        for cell in ("cs1_paper", "joule_600", "joule_370"):
            for mp in pods:
                cells.append(("stencil", cell, "bicgstab_iter", mp))
    elif args.arch and args.arch.startswith("stencil:"):
        for mp in pods:
            cells.append(("stencil", args.arch.split(":", 1)[1], "bicgstab_iter", mp))
    elif args.arch:
        shapes = [args.shape] if args.shape else LM_SHAPES
        for shape in shapes:
            for mp in pods:
                cells.append(("lm", args.arch, shape, mp))
    else:
        ap.error("pass --arch or --all")

    results = run_cells(cells, args.out)
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skipped" for r in results)
    n_err = sum(r.get("status") == "error" for r in results)
    print(f"\n=== dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} cells ===")
    if n_err:
        for r in results:
            if r.get("status") == "error":
                print(" ERROR:", r["arch"], r["shape"], r["mesh"], "-", r["error"])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
