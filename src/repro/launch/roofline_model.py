"""Analytic fused-executor memory model per (arch x shape x mesh) cell.

Why this exists: the dry-run compiles with the XLA *CPU* backend, whose
"bytes accessed" counts every unfused intermediate (attention scores, softmax
temps, cache-update copies) as HBM traffic.  A TPU compile fuses those into
VMEM-resident chains, so the CPU number over-states the memory term by up to
~50x for attention-heavy cells.  This module computes the idealized
fused-executor HBM traffic — weights, boundary activations, KV-cache, MoE
buffers, logits — from first principles, and a static footprint proof
(params + optimizer + cache + remat working set vs 16 GB HBM).

Both numbers are reported side by side in EXPERIMENTS.md §Roofline:
``t_memory_hlo`` (spec-compliant, CPU-HLO bytes) and ``t_memory_est`` (this
model).  Hillclimbing uses deltas, which are meaningful under either.
"""

from __future__ import annotations

import math

from repro.models.model import ShapeSpec
from repro.models.param import physical_spec, _mesh_axis_sizes
from repro.models.transformer import ArchConfig, build_model_defs


HBM_PER_CHIP = 16 * 2 ** 30


def _shard_product(shape, axes, mesh) -> int:
    """Total shard count physical_spec assigns to this array."""
    sizes = _mesh_axis_sizes(mesh)
    spec = physical_spec(tuple(shape), tuple(axes), mesh)
    prod = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            prod *= sizes[ax]
    return prod


def _params_bytes_per_chip(cfg: ArchConfig, mesh) -> float:
    from repro.models.param import ParamDef
    import jax
    defs = build_model_defs(cfg)
    total = 0.0
    import numpy as np
    for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)):
        n = math.prod(d.shape)
        total += n * np.dtype(d.dtype).itemsize / _shard_product(d.shape, d.axes, mesh)
    return total


def lm_cell_memory_estimate(cfg: ArchConfig, shape: ShapeSpec, mesh) -> dict:
    sizes = _mesh_axis_sizes(mesh)
    n_dev = math.prod(sizes.values())
    d_batch = _shard_product((shape.global_batch,), ("batch",), mesh)
    d_model_ax = sizes.get("model", 1)
    B, T = shape.global_batch, shape.seq_len
    itemsize = 2  # bf16 storage

    p_bytes = _params_bytes_per_chip(cfg, mesh)
    kind = shape.kind
    tok = B * (T if kind != "decode" else 1) / d_batch

    # ---- per-layer boundary-activation traffic (fused executor) ----
    d = cfg.d_model
    act = 0.0
    cache_bytes = 0.0
    for spec in cfg.period:
        count = cfg.n_periods
        if spec.kind == "attn":
            ctx = T if kind != "decode" else T     # decode reads full cache
            # residual stream + norms: ~6 passes fwd
            layer = 6 * tok * d * itemsize
            # q/k/v/o boundary tensors
            h_shard = _shard_product((d, cfg.n_heads, cfg.d_head),
                                     ("d_model", "heads", "head_dim"), mesh)
            layer += 6 * tok * cfg.n_heads * cfg.d_head * itemsize / max(h_shard // 1, 1)
            if kind == "decode":
                # read the whole (sharded) KV cache once per step
                kv = B / d_batch * ctx * cfg.n_kv_heads * cfg.d_head * 2 * itemsize
                kv /= _shard_product((B, ctx, cfg.n_kv_heads, cfg.d_head),
                                     ("batch", "kv_seq", "kv_heads", "head_dim"),
                                     mesh) / d_batch
                layer += kv
                cache_bytes += kv
            else:
                # flash: K/V stream once per query chunk; assume 2 passes
                layer += 2 * tok * cfg.n_kv_heads * cfg.d_head * 2 * itemsize
        elif spec.kind == "mamba":
            d_in = cfg.mamba_expand * d
            din_shard = _shard_product((d, d_in), ("d_model", "heads_flat"), mesh)
            layer = 8 * tok * d * itemsize + 6 * tok * d_in * itemsize / max(din_shard, 1)
            # chunked scan states spill once per chunk (chunk=256)
            layer += tok * d_in * cfg.mamba_d_state * 4 / max(din_shard, 1) / 256 * 2
        elif spec.kind == "rwkv":
            layer = 10 * tok * d * itemsize
            layer += 2 * tok * cfg.d_ff * itemsize / d_model_ax
        else:
            layer = 0.0
        if spec.kind != "rwkv":
            if spec.moe:
                cap_f = 1.25
                ff = cfg.d_ff_expert
                # MoE groups may shard beyond the batch axes ("moe_groups")
                g_extra = _shard_product((1 << 20,), ("moe_groups",), mesh) \
                    / max(d_batch, 1)
                layer += 2 * tok * cfg.top_k * cap_f * d * itemsize / max(g_extra, 1)
                layer += 2 * tok * cfg.top_k * cap_f * ff * itemsize / d_model_ax
                if cfg.n_shared_experts:
                    layer += 3 * tok * cfg.n_shared_experts * cfg.d_ff_expert \
                        * itemsize / d_model_ax
            else:
                layer += 3 * tok * cfg.d_ff * itemsize / d_model_ax
        act += layer * count

    # logits + loss (train: chunked over sequence, 8 chunks — model.loss_fn;
    # prefill: last position only)
    v_shard = _shard_product((cfg.vocab, d), ("vocab", "d_model"), mesh)
    logit_tok = tok if kind == "train" else B / d_batch
    logits = logit_tok * cfg.vocab * itemsize / max(v_shard, 1) * (3 if kind == "train" else 1)
    logit_chunks = 8 if kind == "train" else 1

    if kind == "train":
        # fwd + remat-fwd + bwd activation passes; params w/grad/opt traffic
        traffic = p_bytes * (2 + 1) + p_bytes / itemsize * (4 + 4 + 16 + 2) \
            + 3 * act + logits
    elif kind == "prefill":
        traffic = p_bytes + act + logits + cache_bytes
    else:
        traffic = p_bytes + act + logits
    # ---- static footprint (the "fits" proof) ----
    # ZeRO-1: moments (f32, 8B/param) and grads shard over the batch axes too
    zero_shard = max(d_batch, 1)
    opt = p_bytes / itemsize * 8 / zero_shard if kind == "train" else 0.0
    grads = p_bytes * 2 / zero_shard if kind == "train" else 0.0
    cache_static = 0.0
    if kind != "train":
        for spec in cfg.period:
            if spec.kind == "attn":
                sh = _shard_product((B, T, cfg.n_kv_heads, cfg.d_head),
                                    ("batch", "kv_seq", "kv_heads", "head_dim"), mesh)
                cache_static += cfg.n_periods * 2 * B * T * cfg.n_kv_heads \
                    * cfg.d_head * itemsize / sh
            elif spec.kind == "mamba":
                d_in = cfg.mamba_expand * d
                cache_static += cfg.n_periods * B / d_batch * d_in \
                    * (cfg.mamba_d_state * 4 + cfg.mamba_d_conv * 2) / d_model_ax
            elif spec.kind == "rwkv":
                H = d // cfg.rwkv_head_size
                cache_static += cfg.n_periods * B / d_batch \
                    * (H * cfg.rwkv_head_size ** 2 * 4 / d_model_ax + 2 * d * 2)
    # the remat stash and residual stream are sequence-sharded at layer
    # boundaries (Megatron-SP, "seq_act" rule) in full-sequence modes
    seq_shard = _shard_product((B, T, d), ("batch", "seq_act", "d_model"), mesh) \
        / max(d_batch, 1) if kind != "decode" else 1
    remat_stash = (cfg.n_layers * tok * d * itemsize / max(seq_shard, 1)) \
        if kind == "train" else 0.0
    # peak live set ~ 2x one layer's boundary traffic (XLA reuses sequential
    # temps) + the chunked logits buffers
    peak_work = act / max(cfg.n_layers, 1) * 2 + logit_tok * cfg.vocab \
        * itemsize / max(v_shard, 1) * 3 / logit_chunks
    footprint = p_bytes + opt + grads + cache_static + remat_stash + peak_work

    return {
        "est_hbm_traffic_bytes": traffic,
        "est_params_bytes": p_bytes,
        "est_cache_bytes": cache_static,
        "est_footprint_bytes": footprint,
        "est_fits_16gb": bool(footprint < HBM_PER_CHIP),
        "est_footprint_gb": footprint / 2 ** 30,
    }


def stencil_cell_memory_estimate(mesh_shape, n_dev_xy: tuple[int, int, int],
                                 itemsize: int = 2) -> dict:
    """BiCGStab iteration traffic: paper §IV — 10 state vectors/core; per
    iteration 2 fused SpMV sweeps (read 6 coeffs + v, write u) + 6 AXPY
    sweeps + 4 dot reads.  words/pt: spmv 2x(8) + axpy 6x3 + dots 8 = 42."""
    X, Y, Z = mesh_shape
    px, py, pz = n_dev_xy
    pts = X * Y * Z / (px * py * pz)
    words = 2 * 8 + 6 * 3 + 8
    traffic = pts * words * itemsize
    footprint = pts * 10 * itemsize
    return {
        "est_hbm_traffic_bytes": traffic,
        "est_footprint_bytes": footprint,
        "est_fits_16gb": bool(footprint < HBM_PER_CHIP),
        "est_footprint_gb": footprint / 2 ** 30,
    }
