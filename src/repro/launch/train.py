"""Production training driver: mesh + sharded params + data + checkpoints +
fault-tolerant runner, for any assigned architecture.

CPU-scale usage (smoke config, the default):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b --steps 50

Pod-scale usage is identical but with --full and a real TPU runtime; the
driver only touches jax-portable APIs (make_mesh / NamedSharding / jit).
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke
from repro.data import DataConfig, SyntheticLMData
from repro.launch.mesh import make_mesh_for_devices, make_production_mesh
from repro.models import model as M
from repro.optim.adamw import adamw_init
from repro.runtime import FaultTolerantRunner, RunnerConfig


def build(arch: str, *, full: bool = False, seq_len: int = 64,
          global_batch: int = 4, production_mesh: bool = False):
    cfg = get_config(arch) if full else get_smoke(arch)
    mesh = (make_production_mesh() if production_mesh
            else make_mesh_for_devices())
    extras = {}
    if cfg.frontend == "vlm":
        extras["patch_embeds"] = ((cfg.n_frontend_tokens, cfg.d_model), np.float32)
        seq_len_text = seq_len - 0  # image tokens are extra, text len = seq_len
    if cfg.enc_dec:
        extras["frames"] = ((seq_len, cfg.d_model), np.float32)
    data = SyntheticLMData(
        DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch),
        extras=extras)
    return cfg, mesh, data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full published config (pod-scale; default: smoke twin)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    cfg, mesh, data = build(args.arch, full=args.full, seq_len=args.seq,
                            global_batch=args.batch)
    print(f"arch={cfg.name} params={M.n_params(cfg):,} devices={len(jax.devices())}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    from repro.compat import set_mesh
    with set_mesh(mesh):
        if len(jax.devices()) > 1:
            shardings = M.param_shardings(cfg, mesh)
            params = jax.device_put(params, shardings)
        train_step = jax.jit(M.make_train_step(cfg, total_steps=args.steps))

        def stepper(p, o, batch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.frontend == "vlm" or cfg.enc_dec:
                batch = _adapt_modality(cfg, batch)
            return train_step(p, o, batch)

        if args.ckpt_dir:
            runner = FaultTolerantRunner(
                RunnerConfig(total_steps=args.steps,
                             checkpoint_every=args.ckpt_every),
                train_step=stepper, data=data,
                ckpt=CheckpointManager(args.ckpt_dir))
            t0 = time.time()
            params, opt = runner.run(params, opt)
            hist = runner.metrics_history
        else:
            hist = []
            t0 = time.time()
            for step, batch in data.iterate(0):
                if step >= args.steps:
                    break
                params, opt, m = stepper(params, opt, batch)
                hist.append({"step": step, "loss": float(m["loss"])})
                if step % args.log_every == 0:
                    print(f"step {step:5d} loss {float(m['loss']):.4f}")
    dt = time.time() - t0
    losses = [h["loss"] for h in hist]
    print(f"done: {len(losses)} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


def _adapt_modality(cfg, batch):
    b = dict(batch)
    if cfg.frontend == "vlm" and "patch_embeds" in b:
        b["patch_embeds"] = b["patch_embeds"].astype(cfg.dtype)
    if cfg.enc_dec and "frames" in b:
        b["frames"] = b["frames"].astype(cfg.dtype)
    return b


if __name__ == "__main__":
    main()
