"""Production mesh construction.

The paper maps a 3D ``X x Y x Z`` mesh onto a 2D fabric of processing
elements (CS-1: 602 x 595 tiles).  Here the fabric is a TPU pod: a 16 x 16
chip mesh per pod, with a third ``pod`` axis for multi-pod runs.  Axis
meaning is role-dependent:

* stencil solver: ``("data", "model")`` are the fabric (X, Y) axes of the
  paper's Fig. 3; ``pod`` slabs the Z dimension.
* LM stack: ``data`` (x ``pod``) is data-parallel, ``model`` is
  tensor/expert-parallel; decode shapes re-purpose ``model`` for KV-cache
  sequence sharding.

Everything is a function (never module-level state) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax


def _make_mesh(shape, axis_names):
    """jax.make_mesh, version-gated (see repro.compat.make_mesh)."""
    from repro.compat import make_mesh
    return make_mesh(shape, axis_names)


def make_production_mesh(*, multi_pod: bool = False):
    """The target mesh: one pod = 16 x 16 = 256 chips; two pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int | None = None, *, pods: int = 1):
    """Largest near-square 2D (or 3D with pods) mesh for the available devices.

    Used by tests and CPU-scale examples; on a 1-device CPU this degenerates
    to a 1x1 mesh and all collectives become no-ops (boundary semantics are
    preserved because ppermute fills non-received shards with zeros).
    """
    if n_devices is None:
        n_devices = len(jax.devices())
    per_pod = n_devices // pods
    x = 1
    for cand in range(int(per_pod ** 0.5), 0, -1):
        if per_pod % cand == 0:
            x = cand
            break
    y = per_pod // x
    if pods > 1:
        return _make_mesh((pods, x, y), ("pod", "data", "model"))
    return _make_mesh((x, y), ("data", "model"))


def fabric_shape(mesh) -> tuple[int, int, int]:
    """(pods, fabric_x, fabric_y) of a production-style mesh."""
    ax = dict(zip(mesh.axis_names, mesh.devices.shape))
    return ax.get("pod", 1), ax["data"], ax["model"]
