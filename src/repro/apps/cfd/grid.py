"""Staggered MAC grid bookkeeping for the SIMPLE CFD application.

Storage layout: every field is a cell-shaped ``(n, n)`` array so the three
linear systems of one SIMPLE iteration all live on the *same* mesh and shard
identically under ``shard_map`` (the whole point of the apps/cfd refactor —
one ``PartitionSpec`` serves momentum and continuity alike):

* ``u[i, j]``  — x-velocity at the EAST face of cell ``(i, j)``
  (staggered face ``i+1``; the west boundary face is not stored — it is a
  known boundary value: 0 at a wall, ``u_in`` at a channel inlet);
* ``v[i, j]``  — y-velocity at the NORTH face of cell ``(i, j)``
  (staggered face ``j+1``; the south boundary face is the wall);
* ``p[i, j]``  — pressure at the cell center.

The classic ``(n+1, n)`` / ``(n, n+1)`` staggered arrays remain the public
I/O format of the legacy ``core.simple_cfd`` surface; :func:`to_staggered` /
:func:`from_staggered` convert.  With cell-shaped storage, the zero filled
into halos by ``gather_halo`` at fabric edges *is* the no-slip wall value,
so boundary conditions and SPMD decomposition use one mechanism.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.precision import Policy, F32

SCENARIOS = ("cavity", "channel")


@dataclasses.dataclass
class CFDConfig:
    """SIMPLE configuration (field order keeps ``CavityConfig`` compatible).

    The inner-solve limits follow the paper: "limited to 5 iterations for
    transport [and] 20 for continuity".  ``dt=None`` is the steady SIMPLE
    loop; a finite ``dt`` adds the implicit-Euler inertial term and the
    driver marches ``outer_iters``-relaxed outer loops per time step.
    """

    n: int = 32                 # cells per side
    reynolds: float = 100.0
    lid_velocity: float = 1.0
    alpha_u: float = 0.7        # momentum under-relaxation
    alpha_p: float = 0.3        # pressure under-relaxation
    outer_iters: int = 200
    inner_tol: float = 1e-4     # paper: solver limited to a few iterations
    inner_iters_mom: int = 5    # paper: "limited to 5 iterations for transport"
    inner_iters_p: int = 20     # paper: "20 for continuity"
    tol: float = 1e-5
    policy: Policy = F32
    scenario: str = "cavity"    # "cavity" | "channel"
    u_in: float = 1.0           # channel inflow velocity
    dt: float | None = None     # None => steady; finite => transient term

    def __post_init__(self):
        if self.scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r}; have {SCENARIOS}")


#: Legacy name (seed API) — same dataclass, cavity defaults.
CavityConfig = CFDConfig


def cell_state(cfg: CFDConfig):
    """Zero-initialized (u, v, p) cell-shaped state."""
    z = jnp.zeros((cfg.n, cfg.n), jnp.float32)
    return z, z, z


def to_staggered(u: jax.Array, v: jax.Array):
    """Cell-shaped (u, v) -> classic staggered ``(n+1, n)`` / ``(n, n+1)``.

    The prepended boundary face is the homogeneous wall value; channel inlet
    faces carry ``u_in`` only inside the solver (they are boundary data, not
    state), so the staggered view shows the stored faces plus zero walls.
    """
    n = u.shape[1]
    u_stag = jnp.concatenate([jnp.zeros((1, n), u.dtype), u], axis=0)
    v_stag = jnp.concatenate([jnp.zeros((v.shape[0], 1), v.dtype), v], axis=1)
    return u_stag, v_stag


def from_staggered(u_stag: jax.Array, v_stag: jax.Array):
    """Inverse of :func:`to_staggered` (drops the known boundary faces)."""
    return u_stag[1:, :], v_stag[:, 1:]


def centerline_u(u: jax.Array) -> jax.Array:
    """u along the vertical centerline of a *staggered* field (Ghia et al.)."""
    return u[u.shape[0] // 2, :]


def global_indices(n: int, shape: tuple[int, int], ox, oy):
    """(gi, gj) global cell-index grids of a local block at offset (ox, oy).

    Broadcastable ``(bx, 1)`` / ``(1, by)`` — boundary masks (walls, inlet,
    outlet, reference cell) compare against these so the same formation code
    runs undistributed (ox = oy = 0) and inside ``shard_map``
    (ox = axis_index * block).
    """
    bx, by = shape
    gi = (ox + jnp.arange(bx))[:, None]
    gj = (oy + jnp.arange(by))[None, :]
    return gi, gj
