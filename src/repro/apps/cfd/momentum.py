"""Momentum-system formation (paper §VI Alg. 2, the "forming" half of
Table II): first-order upwind convection + central diffusion on the
staggered MAC grid, with Patankar in-equation under-relaxation.

All formation arithmetic runs in float32 regardless of the solver policy:
the ``aP`` clamp and every division (off-diagonal normalization, the SIMPLE
``d`` coefficient) happen *before* the cast to ``policy.storage`` — clamping
in a 16-bit storage dtype can flush a tiny diagonal to zero and poison the
whole pressure correction (the bf16_mixed bug this layer fixes).

Inputs are halo-padded local blocks (``gather_halo(..., corners=True)`` —
the cross-velocity face averages read diagonal neighbors), plus global index
grids for boundary masks, so the same code forms the local rows of the
global matrix undistributed and inside ``shard_map``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.cfd.grid import CFDConfig

#: storage-dtype-independent clamp floor for the momentum/continuity diagonal
AP_FLOOR = 1e-12


def window(padded: jax.Array, di: int, dj: int) -> jax.Array:
    """Block-shaped window of a radius-1 halo-padded block, shifted (di, dj)."""
    bx, by = padded.shape[0] - 2, padded.shape[1] - 2
    return padded[1 + di:1 + di + bx, 1 + dj:1 + dj + by]


def upwind_coeffs(Fe, Fw, Fn, Fs, D):
    """First-order upwind + central diffusion link coefficients."""
    aE = D + jnp.maximum(-Fe, 0.0)
    aW = D + jnp.maximum(Fw, 0.0)
    aN = D + jnp.maximum(-Fn, 0.0)
    aS = D + jnp.maximum(Fs, 0.0)
    aP = aE + aW + aN + aS + (Fe - Fw) + (Fn - Fs)
    return aP, aE, aW, aN, aS


def _relax_and_d(cfg: CFDConfig, aP, b, x_now, x_t, h):
    """Transient term, Patankar relaxation, diagonal clamp, SIMPLE ``d``.

    Clamp and division happen here, in f32 — before any storage cast.
    """
    if cfg.dt is not None:
        at = h * h / cfg.dt
        aP = aP + at
        b = b + at * x_t
    aP = aP / cfg.alpha_u
    b = b + (1.0 - cfg.alpha_u) * aP * x_now
    aP = jnp.maximum(aP, AP_FLOOR)
    d = h / aP
    return aP, b, d


def form_u_system(cfg: CFDConfig, up, vp, pp, u, u_t, gi, gj):
    """u-momentum rows for every stored east face.

    ``up``/``vp``/``pp`` are halo-padded f32 blocks of the OLD fields (both
    momentum systems form from the same time/outer level, as in Alg. 2);
    ``u`` is the unpadded current block (relaxation anchor), ``u_t`` the
    previous time level.  Returns ``(aP, aE, aW, aN, aS, b, du)`` with
    boundary rows already folded in.
    """
    n = cfg.n
    h = 1.0 / n
    D = 1.0 / cfg.reynolds           # mu; rho = U = L = 1
    channel = cfg.scenario == "channel"

    # face fluxes seen by the u-control-volume around east face (gi, gj)
    Fe = 0.5 * h * (window(up, 0, 0) + window(up, 1, 0))
    Fw = 0.5 * h * (window(up, -1, 0) + window(up, 0, 0))
    Fn = 0.5 * h * (window(vp, 0, 0) + window(vp, 1, 0))
    Fs = 0.5 * h * (window(vp, 0, -1) + window(vp, 1, -1))
    if channel:
        # inlet face carries u_in, not the zero the wall halo provided
        Fw = jnp.where(gi == 0, Fw + 0.5 * h * cfg.u_in, Fw)
    aP, aE, aW, aN, aS = upwind_coeffs(Fe, Fw, Fn, Fs, D)

    b = (window(pp, 0, 0) - window(pp, 1, 0)) * h
    # no-slip top/bottom: wall shear via half-cell diffusion; lid adds source
    lid = cfg.lid_velocity if cfg.scenario == "cavity" else 0.0
    aP = aP + jnp.where((gj == 0) | (gj == n - 1), 2.0 * D, 0.0)
    b = b + jnp.where(gj == n - 1, 2.0 * D * lid, 0.0)
    aN = jnp.where(gj == n - 1, 0.0, aN)
    aS = jnp.where(gj == 0, 0.0, aS)
    if channel:
        # inlet: the west neighbor is the known boundary face u_in
        b = b + jnp.where(gi == 0, aW * cfg.u_in, 0.0)
        aW = jnp.where(gi == 0, 0.0, aW)

    aP, b, du = _relax_and_d(cfg, aP, b, u, u_t, h)

    # last stored face: right wall (cavity, value 0) or zero-gradient outlet
    last = gi == n - 1
    aP = jnp.where(last, 1.0, aP)
    aE = jnp.where(last, 0.0, aE)
    aW = jnp.where(last, 1.0 if channel else 0.0, aW)
    aN = jnp.where(last, 0.0, aN)
    aS = jnp.where(last, 0.0, aS)
    b = jnp.where(last, 0.0, b)
    du = jnp.where(last, 0.0, du)
    return aP, aE, aW, aN, aS, b, du


def form_v_system(cfg: CFDConfig, up, vp, pp, v, v_t, gi, gj):
    """v-momentum rows for every stored north face (mirror of the u system)."""
    n = cfg.n
    h = 1.0 / n
    D = 1.0 / cfg.reynolds
    channel = cfg.scenario == "channel"

    Fn = 0.5 * h * (window(vp, 0, 0) + window(vp, 0, 1))
    Fs = 0.5 * h * (window(vp, 0, -1) + window(vp, 0, 0))
    Fe = 0.5 * h * (window(up, 0, 0) + window(up, 0, 1))
    Fw = 0.5 * h * (window(up, -1, 0) + window(up, -1, 1))
    if channel:
        Fw = jnp.where(gi == 0, Fw + h * cfg.u_in, Fw)  # both corner faces = u_in
    aP, aE, aW, aN, aS = upwind_coeffs(Fe, Fw, Fn, Fs, D)

    b = (window(pp, 0, 0) - window(pp, 0, 1)) * h
    # no-slip left/right walls (cavity); channel: inlet is a v=0 Dirichlet
    # face (same half-cell fold), outlet is zero-gradient (no fold, aE open)
    wall_lo = gi == 0
    wall_hi = gi == n - 1
    aP = aP + jnp.where(wall_lo, 2.0 * D, 0.0)
    if channel:
        aE = jnp.where(wall_hi, 0.0, aE)        # zero-gradient outlet
    else:
        aP = aP + jnp.where(wall_hi, 2.0 * D, 0.0)
        aE = jnp.where(wall_hi, 0.0, aE)
    aW = jnp.where(wall_lo, 0.0, aW)

    aP, b, dv = _relax_and_d(cfg, aP, b, v, v_t, h)

    # last stored face: the top wall (v = 0) in both scenarios
    last = gj == n - 1
    aP = jnp.where(last, 1.0, aP)
    aE = jnp.where(last, 0.0, aE)
    aW = jnp.where(last, 0.0, aW)
    aN = jnp.where(last, 0.0, aN)
    aS = jnp.where(last, 0.0, aS)
    b = jnp.where(last, 0.0, b)
    dv = jnp.where(last, 0.0, dv)
    return aP, aE, aW, aN, aS, b, dv
