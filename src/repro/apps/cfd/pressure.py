"""Pressure-correction (continuity) system of SIMPLE (paper §VI Alg. 2).

The p' equation couples cells through the momentum ``d = h/aP`` face
coefficients; boundary faces (walls, channel inlet where the velocity is
prescribed, zero-gradient outlet) carry ``d = 0`` — they are excluded from
the correction, which the momentum layer already encodes by zeroing ``d``
on its identity rows.  The pure-Neumann system is singular, so one
reference cell is pinned.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.apps.cfd.grid import CFDConfig
from repro.apps.cfd.momentum import window


def divergence(cfg: CFDConfig, u_star, v_star, usp, vsp, gi):
    """Cell continuity defect of the starred field, ``(∂u + ∂v) · h``.

    ``usp``/``vsp`` are the halo-padded starred fields (west/south neighbor
    faces).  At a channel inlet the west face is the prescribed ``u_in``
    rather than the zero the wall halo provides.
    """
    h = 1.0 / cfg.n
    div = (u_star - window(usp, -1, 0) + v_star - window(vsp, 0, -1)) * h
    if cfg.scenario == "channel":
        div = div - jnp.where(gi == 0, h * cfg.u_in, 0.0)
    return div


def form_pressure_system(cfg: CFDConfig, du, dv, dup, dvp, div, gi, gj):
    """p'-equation rows: ``aE = dE·h`` at interior faces, 0 at boundaries.

    Returns ``(aP, aE, aW, aN, aS, b)``; the reference cell (0, 0) is pinned
    to lift the Neumann singularity.
    """
    h = 1.0 / cfg.n
    aE = du * h
    aW = window(dup, -1, 0) * h
    aN = dv * h
    aS = window(dvp, 0, -1) * h
    aP = aE + aW + aN + aS
    aP = aP + jnp.where((gi == 0) & (gj == 0), 1.0, 0.0)
    return aP, aE, aW, aN, aS, -div
