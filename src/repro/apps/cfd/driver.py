"""SIMPLE drivers: the application loop over the pluggable solver stack.

One SIMPLE outer iteration (paper §VI Alg. 2) is: form u/v momentum systems,
solve each with a few Krylov iterations, form the pressure-correction
system, solve it, under-relaxed correct.  Here every inner solve goes
through the same registries as ``launch/solve.py`` — ``core.operator``
backends (reference / spmd), ``core.solvers`` (bicgstab / cg) and
``core.precond`` — so ``--solver/--backend/--precond/--policy`` mean the
same thing for the CFD application as for the bare stencil solve.

Distribution: with a multi-device mesh the *whole* outer iteration runs
inside one ``shard_map`` — matrix formation reads neighbor face velocities
via ``gather_halo`` (corner-carrying, the cross-velocity averages touch
diagonal neighbors), and the formed rows feed the distributed solver loop
unchanged (its SpMV does its own depth-1 halo exchanges, its dots psum over
the fabric).  The communication per outer iteration is therefore exactly:
formation halos + (inner iterations x the solver's 3-AllReduce schedule).

Transient mode adds the implicit-Euler inertial term and marches
checkpointed time steps through ``checkpoint.CheckpointManager`` +
``runtime.FaultTolerantRunner`` (restart replays bit-identically — the step
is deterministic in the restored state).
"""

from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.apps.cfd.grid import (
    CFDConfig, cell_state, from_staggered, global_indices, to_staggered,
)
from repro.apps.cfd.momentum import AP_FLOOR, form_u_system, form_v_system, window
from repro.apps.cfd.pressure import divergence, form_pressure_system
from repro.compat import shard_map
from repro.core.halo import FabricAxes, gather_halo
from repro.core.operator import BACKENDS, make_operator
from repro.core.precond import PrecondConfig, build_precond
from repro.core.solvers import get_solver
from repro.core.stencil import StencilCoeffs
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class SolverOptions:
    """Which pieces of the solver stack the inner solves are routed through.

    ``normalize=True`` is the paper's scheme: rows pre-scaled to unit
    diagonal before the solve ("we only store six other diagonals"), where
    Jacobi preconditioning is the identity.  ``normalize=False`` hands the
    solver the *raw* rows with the stored ``aP`` diagonal — the case where
    ``precond="jacobi"`` does real work through the registry.

    ``schedule`` is the halo communication schedule every inner solve's
    operator is built with (``core.comm.SCHEDULES``; ``overlap`` hides the
    exchange under the interior apply, bit-identically).  ``p_solver``
    optionally routes the pressure-correction solve through a different
    registry entry than the momentum solves — the pressure system is the
    iteration-dominant one, so e.g. ``p_solver="pipelined_bicgstab"`` puts
    the single-AllReduce schedule exactly where the sync points are.
    """

    solver: str = "bicgstab"
    backend: str = "reference"
    precond: str | PrecondConfig = "none"
    normalize: bool = True
    cheb_degree: int = 3
    schedule: str = "overlap"
    p_solver: str | None = None

    def precond_config(self) -> PrecondConfig:
        if isinstance(self.precond, PrecondConfig):
            return self.precond
        return PrecondConfig(name=self.precond, degree=self.cheb_degree)

    @property
    def pressure_solver(self) -> str:
        return self.p_solver or self.solver


def _reduce_names(fabric: FabricAxes) -> tuple[str, ...]:
    return tuple(a for a, k in ((fabric.x, fabric.nx), (fabric.y, fabric.ny))
                 if a is not None and k > 1)


def _pmax(x, names):
    return jax.lax.pmax(x, names) if names else x


def _psum(x, names):
    return jax.lax.psum(x, names) if names else x


def _system_coeffs(opts: SolverOptions, policy, system, b):
    """(aP, aE, aW, aN, aS), b -> solver-facing (StencilCoeffs, rhs).

    The normalization divisions run in f32 on the clamped diagonal; only the
    finished coefficients are cast to ``policy.storage`` (the bf16 clamp
    bugfix — see momentum.py).
    """
    aP, aE, aW, aN, aS = system
    aP = jnp.maximum(aP, AP_FLOOR)
    if opts.normalize:
        inv = 1.0 / aP
        cf = StencilCoeffs({"xp": -aE * inv, "xm": -aW * inv,
                            "yp": -aN * inv, "ym": -aS * inv})
        b = b * inv
    else:
        cf = StencilCoeffs({"xp": -aE, "xm": -aW, "yp": -aN, "ym": -aS},
                           diag=aP)
    return cf.astype(policy.storage), b.astype(policy.storage)


def _inner_solve(cfg: CFDConfig, opts: SolverOptions, pconf: PrecondConfig,
                 fabric: FabricAxes, system, b, x0, iters: int,
                 solver: str | None = None):
    """One registry-routed inner solve; returns the f32 solution field.

    ``solver`` overrides ``opts.solver`` (the pressure solve passes
    ``opts.pressure_solver``)."""
    pol = cfg.policy
    cf, bs = _system_coeffs(opts, pol, system, b)
    # Pin the formation/solve boundary: without it XLA fuses formation
    # arithmetic into the solver subgraph, and that fusion (FMA contraction
    # included) depends on the comm schedule's apply structure — an
    # ulp-level perturbation the Krylov loop amplifies.  With the barrier
    # the solver sees materialized systems, so blocking and overlap
    # schedules stay bit-identical through the whole SIMPLE iteration.
    cf, bs, x0 = jax.lax.optimization_barrier((cf, bs, x0))
    op = make_operator(opts.backend, cf, fabric, policy=pol,
                       schedule=opts.schedule)
    M = build_precond(pconf, op)
    res = get_solver(solver or opts.solver)(
        op, bs, x0.astype(pol.storage), tol=cfg.inner_tol, maxiter=iters,
        policy=pol, precond=M)
    return res.x.astype(jnp.float32)


def _step_local(cfg: CFDConfig, opts: SolverOptions, pconf: PrecondConfig,
                fabric: FabricAxes, red: tuple[str, ...],
                u, v, p, u_t, v_t, ox, oy, *, form_only: bool = False):
    """One SIMPLE outer iteration on the local block (runs plain or inside
    shard_map — ``fabric``/``red``/``ox``/``oy`` carry the difference)."""
    n = cfg.n
    h = 1.0 / n
    gi, gj = global_indices(n, u.shape, ox, oy)

    # ---- formation halos (old fields; corners for cross-velocity reads) --
    up = gather_halo(u, fabric, 1, corners=True)
    vp = gather_halo(v, fabric, 1, corners=True)
    pp = gather_halo(p, fabric, 1)
    aPu, aEu, aWu, aNu, aSu, bu, du = form_u_system(cfg, up, vp, pp, u, u_t, gi, gj)
    aPv, aEv, aWv, aNv, aSv, bv, dv = form_v_system(cfg, up, vp, pp, v, v_t, gi, gj)

    if form_only:
        # benchmark slice: all three systems formed, nothing solved — the
        # continuity rows are formed from the unstarred field
        usp = gather_halo(u, fabric, 1)
        vsp = gather_halo(v, fabric, 1)
        div0 = divergence(cfg, u, v, usp, vsp, gi)
        dup = gather_halo(du, fabric, 1)
        dvp = gather_halo(dv, fabric, 1)
        psys = form_pressure_system(cfg, du, dv, dup, dvp, div0, gi, gj)
        parts = (aPu, bu, du, aPv, bv, dv) + psys
        return _psum(sum(a.sum() for a in parts), red)

    # ---- momentum predictors ---------------------------------------------
    u_star = _inner_solve(cfg, opts, pconf, fabric,
                          (aPu, aEu, aWu, aNu, aSu), bu, u,
                          cfg.inner_iters_mom)
    v_star = _inner_solve(cfg, opts, pconf, fabric,
                          (aPv, aEv, aWv, aNv, aSv), bv, v,
                          cfg.inner_iters_mom)
    mom_res_u = _pmax(jnp.abs(u_star - u).max(), red)

    if cfg.scenario == "channel":
        # global mass defect folded onto the zero-gradient outlet faces so
        # the pressure correction sees a solvable (net-zero-source) system
        influx = jnp.float32(cfg.u_in)          # u_in * n faces * h = u_in
        out_faces = jnp.where(gi == n - 1, u_star, 0.0)
        outflux = h * _psum(out_faces.sum(), red)
        u_star = jnp.where(gi == n - 1,
                           u_star + (influx - outflux) / (n * h), u_star)

    # ---- pressure correction ---------------------------------------------
    usp = gather_halo(u_star, fabric, 1)
    vsp = gather_halo(v_star, fabric, 1)
    div = divergence(cfg, u_star, v_star, usp, vsp, gi)
    dup = gather_halo(du, fabric, 1)
    dvp = gather_halo(dv, fabric, 1)
    aPp, aEp, aWp, aNp, aSp, bp = form_pressure_system(
        cfg, du, dv, dup, dvp, div, gi, gj)
    p_corr = _inner_solve(cfg, opts, pconf, fabric,
                          (aPp, aEp, aWp, aNp, aSp), bp, jnp.zeros_like(p),
                          cfg.inner_iters_p, solver=opts.pressure_solver)

    # ---- under-relaxed corrections ---------------------------------------
    pcp = gather_halo(p_corr, fabric, 1)
    u_new = u_star + du * (p_corr - window(pcp, 1, 0))
    v_new = v_star + dv * (p_corr - window(pcp, 0, 1))
    p_new = p + cfg.alpha_p * p_corr
    cont_res = _pmax(jnp.abs(div).max(), red)
    return u_new, v_new, p_new, cont_res, mom_res_u


def _validate(cfg: CFDConfig, opts: SolverOptions, mesh) -> None:
    from repro.core.comm import SCHEDULES
    from repro.core.solvers import SOLVERS

    if opts.backend not in BACKENDS:
        raise KeyError(f"unknown backend {opts.backend!r}; have {sorted(BACKENDS)}")
    if opts.schedule not in SCHEDULES:
        raise KeyError(f"unknown comm schedule {opts.schedule!r}; "
                       f"have {sorted(SCHEDULES)}")
    for s in (opts.solver, opts.pressure_solver):
        if s not in SOLVERS:
            raise KeyError(f"unknown solver {s!r}; have {sorted(SOLVERS)}")
    if opts.backend == "pallas":
        raise NotImplementedError(
            "the 2D CFD fields have no Pallas kernel yet; use backend='spmd' "
            "(same shard_map/halo path, jnp local apply)")
    if mesh is not None and opts.backend == "reference" and mesh.devices.size > 1:
        raise ValueError(
            "backend='reference' is single-address-space only; use "
            "backend='spmd' on a multi-device mesh")


def make_step_fn(cfg: CFDConfig, opts: SolverOptions = SolverOptions(),
                 mesh=None, *, form_only: bool = False):
    """Compile one SIMPLE outer iteration.

    Returns ``step(u, v, p, u_t, v_t) -> (u, v, p, cont_res, mom_res_u)``
    on cell-shaped fields (``u_t``/``v_t`` are the previous time level,
    ignored when ``cfg.dt is None`` — pass the current fields).  With a mesh
    and a distributed backend the whole iteration (formation + inner
    solves) is one ``shard_map``.
    """
    _validate(cfg, opts, mesh)
    pconf = opts.precond_config()

    if mesh is None or opts.backend == "reference" or mesh.devices.size == 1:
        fabric = FabricAxes()

        def step(u, v, p, u_t, v_t):
            return _step_local(cfg, opts, pconf, fabric, (), u, v, p,
                               u_t, v_t, 0, 0, form_only=form_only)

        return jax.jit(step)

    fabric = FabricAxes.from_mesh(mesh)
    if fabric.nz > 1:
        raise ValueError("the 2D CFD app needs a 2D fabric (no pod axis)")
    if cfg.n % fabric.nx or cfg.n % fabric.ny:
        raise ValueError(
            f"n={cfg.n} must divide the fabric {fabric.nx}x{fabric.ny}")
    bx, by = cfg.n // fabric.nx, cfg.n // fabric.ny
    red = _reduce_names(fabric)

    def local(u, v, p, u_t, v_t):
        ox = jax.lax.axis_index(fabric.x) * bx
        oy = jax.lax.axis_index(fabric.y) * by
        return _step_local(cfg, opts, pconf, fabric, red, u, v, p,
                           u_t, v_t, ox, oy, form_only=form_only)

    spec = P(fabric.x, fabric.y)
    scalar = P()
    out_specs = scalar if form_only else (spec, spec, spec, scalar, scalar)
    mapped = shard_map(local, mesh=mesh, in_specs=(spec,) * 5,
                       out_specs=out_specs, check_vma=False)
    return jax.jit(mapped)


# ---------------------------------------------------------------------------
# Steady drivers (and the legacy core.simple_cfd surface)
# ---------------------------------------------------------------------------

def solve_steady(cfg: CFDConfig, opts: SolverOptions = SolverOptions(),
                 mesh=None):
    """Run SIMPLE to convergence; returns cell-shaped (u, v, p, history)."""
    cfg = dataclasses.replace(cfg, dt=None)
    u, v, p = cell_state(cfg)
    step = make_step_fn(cfg, opts, mesh)
    history = []
    for i in range(cfg.outer_iters):
        with obs_trace.span("cfd.outer", i=i, solver=opts.solver,
                            backend=opts.backend) as sp:
            u, v, p, res, mres = step(u, v, p, u, v)
            res = sp.block(res)
        obs_metrics.counter("cfd.outer_iterations").inc()
        obs_metrics.gauge("cfd.continuity_res").set(float(res))
        obs_metrics.gauge("cfd.mom_res_u").set(float(mres))
        history.append(float(res))
        if history[-1] < cfg.tol:
            break
    obs_metrics.event("cfd_steady", scenario=cfg.scenario, n=cfg.n,
                      outer_iterations=len(history),
                      continuity_res=history[-1] if history else None,
                      converged=bool(history and history[-1] < cfg.tol))
    return u, v, p, history


def solve_cavity(cfg: CFDConfig, opts: SolverOptions = SolverOptions(),
                 mesh=None):
    """Legacy surface: staggered (u, v, p, history) of the steady cavity."""
    u, v, p, history = solve_steady(cfg, opts, mesh)
    u_stag, v_stag = to_staggered(u, v)
    return u_stag, v_stag, p, history


def simple_step(cfg: CFDConfig, u, v, p, *, opts: SolverOptions = SolverOptions()):
    """Legacy surface: one SIMPLE iteration on *staggered* fields.

    Same signature/returns as the seed's ``core.simple_cfd.simple_step``;
    the body now routes through the registry stack (reference backend).
    """
    uc, vc = from_staggered(u, v)
    un, vn, pn, res, mres = _step_local(
        cfg, opts, opts.precond_config(), FabricAxes(), (),
        uc, vc, p, uc, vc, 0, 0)
    us, vs = to_staggered(un, vn)
    return us, vs, pn, res, {"mom_res_u": mres}


def measure_solve_share(cfg: CFDConfig, opts: SolverOptions, mesh, state, *,
                        reps: int = 3) -> dict:
    """Paper Table II accounting: the fraction of one SIMPLE outer
    iteration spent in the linear solves vs forming the systems.

    The full step and a formation-only variant (same halo gathers, same
    three systems, no solves) are timed separately; the difference is
    attributed to the solves.  The split lands in the observability
    registry (``cfd.solve_share`` / ``cfd.form_share`` gauges plus a
    ``cfd_solve_share`` event) so every run reports the paper's 50-70%
    MFIX band the same way — ``benchmarks/cfd_step.py`` is a sweep over
    this function, not a bespoke accounting of its own.
    """
    import time

    u, v, p = state
    step = make_step_fn(cfg, opts, mesh)
    form = make_step_fn(cfg, opts, mesh, form_only=True)

    def timed(fn):
        jax.block_until_ready(fn(u, v, p, u, v))     # compile + warm
        t0 = time.time()
        for _ in range(reps):
            out = fn(u, v, p, u, v)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps

    with obs_trace.span("cfd.measure_solve_share", backend=opts.backend):
        t_full = timed(step)
        t_form = timed(form)
    t_solve = max(t_full - t_form, 0.0)
    solve_share = t_solve / t_full
    obs_metrics.gauge("cfd.step_ms").set(t_full * 1e3)
    obs_metrics.gauge("cfd.solve_share").set(solve_share)
    obs_metrics.gauge("cfd.form_share").set(t_form / t_full)
    split = {
        "backend": opts.backend,
        "precond": (opts.precond if isinstance(opts.precond, str)
                    else opts.precond.name),
        "rows": "unit-diagonal" if opts.normalize else "raw",
        "step_ms": t_full * 1e3,
        "form_ms": t_form * 1e3,
        "solve_ms": t_solve * 1e3,
        "solve_pct": 100.0 * solve_share,
        "form_pct": 100.0 * t_form / t_full,
    }
    obs_metrics.event("cfd_solve_share", **split)
    return split


# ---------------------------------------------------------------------------
# Transient, checkpointed driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransientConfig:
    """Time-marching knobs: implicit-Euler steps of ``dt``, each stepped to
    (approximate) convergence by ``outers_per_step`` under-relaxed SIMPLE
    outer iterations, checkpointed every ``checkpoint_every`` steps."""

    dt: float = 0.02
    n_steps: int = 50
    outers_per_step: int = 20
    checkpoint_every: int = 10
    max_restarts: int = 3
    async_checkpoint: bool = False


class _StepStream:
    """Duck-types the runner's data pipeline: stateless (step, batch=None)."""

    def iterate(self, start_step: int):
        return ((s, None) for s in itertools.count(start_step))


def make_transient_step(cfg: CFDConfig, tcfg: TransientConfig,
                        opts: SolverOptions = SolverOptions(), mesh=None):
    """``timestep(state) -> (state, metrics)`` advancing one dt."""
    cfg = dataclasses.replace(cfg, dt=tcfg.dt)
    step = make_step_fn(cfg, opts, mesh)

    def timestep(state):
        u, v, p = state
        u_t, v_t = u, v
        res = mres = jnp.float32(0.0)
        with obs_trace.span("cfd.timestep",
                            outers=tcfg.outers_per_step) as sp:
            for i in range(tcfg.outers_per_step):
                with obs_trace.span("cfd.outer", i=i, solver=opts.solver):
                    u, v, p, res, mres = step(u, v, p, u_t, v_t)
                obs_metrics.counter("cfd.outer_iterations").inc()
            res = sp.block(res)
        obs_metrics.counter("cfd.timesteps").inc()
        obs_metrics.gauge("cfd.continuity_res").set(float(res))
        return (u, v, p), {"continuity": res, "mom_res_u": mres}

    return timestep


def run_transient(cfg: CFDConfig, tcfg: TransientConfig,
                  opts: SolverOptions = SolverOptions(), mesh=None, *,
                  checkpoint_dir: str | None = None, failure_hook=None):
    """March ``n_steps`` time steps; returns (final state, metrics history).

    With ``checkpoint_dir`` the march runs under ``FaultTolerantRunner``:
    periodic (optionally async) checkpoints, restore-and-replay on any step
    failure, and resume-from-latest when the directory already holds a
    checkpoint — long runs survive preemption.  Restart is deterministic:
    the restored state replays to bit-identical fields.
    """
    from repro.checkpoint import CheckpointManager
    from repro.runtime import FaultTolerantRunner, RunnerConfig

    timestep = make_transient_step(cfg, tcfg, opts, mesh)
    state = cell_state(cfg)

    if checkpoint_dir is None:
        metrics = []
        for s in range(tcfg.n_steps):
            state, m = timestep(state)
            metrics.append({"step": s, **{k: float(x) for k, x in m.items()}})
        return state, metrics

    def train_step(params, opt_state, batch):
        new_state, m = timestep(params)
        return new_state, opt_state, m

    runner = FaultTolerantRunner(
        RunnerConfig(total_steps=tcfg.n_steps,
                     checkpoint_every=tcfg.checkpoint_every,
                     max_restarts=tcfg.max_restarts,
                     async_checkpoint=tcfg.async_checkpoint),
        train_step=train_step, data=_StepStream(),
        ckpt=CheckpointManager(checkpoint_dir, keep=3),
        failure_hook=failure_hook)
    final_state, _ = runner.run(state, ())
    # a fault replay re-appends the steps between the restored checkpoint
    # and the failure point; keep one (the replayed, i.e. last) entry per step
    by_step = {m["step"]: m for m in runner.metrics_history}
    return final_state, [by_step[s] for s in sorted(by_step)]
