"""Distributed SIMPLE CFD application (paper §VI Alg. 2) on the solver stack.

Layers (each its own module):

* :mod:`~repro.apps.cfd.grid`     — MAC-grid storage, configs, conversions;
* :mod:`~repro.apps.cfd.momentum` — u/v momentum-system formation (upwind +
  diffusion, under-relaxation, the f32 clamp-before-cast rule);
* :mod:`~repro.apps.cfd.pressure` — continuity defect + p'-system formation;
* :mod:`~repro.apps.cfd.driver`   — SIMPLE outer loop over the operator/
  solver/precond registries, steady + transient (checkpointed) drivers.

``core.simple_cfd`` re-exports the legacy seed surface from here.
"""

from repro.apps.cfd.grid import (  # noqa: F401
    CavityConfig, CFDConfig, cell_state, centerline_u, from_staggered,
    to_staggered,
)
from repro.apps.cfd.driver import (  # noqa: F401
    SolverOptions, TransientConfig, make_step_fn, make_transient_step,
    run_transient, simple_step, solve_cavity, solve_steady,
)

__all__ = [
    "CFDConfig", "CavityConfig", "SolverOptions", "TransientConfig",
    "cell_state", "centerline_u", "from_staggered", "to_staggered",
    "make_step_fn", "make_transient_step", "run_transient", "simple_step",
    "solve_cavity", "solve_steady",
]
