"""Application subsystems built on the solver stack (paper §VI: "plans to
extend this work towards full applications")."""
