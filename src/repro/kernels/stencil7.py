"""Deprecation shim: the 7-point kernel package, collapsed.

Historically ``kernels/stencil7/`` carried its own fused Pallas kernel
(the paper's Listing 1, TPU-native) plus wrappers and a jnp oracle.  All
of that now lives, shape-parameterized, in :mod:`repro.kernels.stencil_nd`
— this single file re-exports the radius-1 star specialization under the
legacy names so existing callers keep working.  New code should import
from ``kernels/stencil_nd`` directly.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.stencil import STAR7, StencilCoeffs

warnings.warn(
    "repro.kernels.stencil7 is deprecated: the 7-point kernel lives, "
    "shape-parameterized, in repro.kernels.stencil_nd — import from there. "
    "This shim re-exports the legacy names and will be removed.",
    DeprecationWarning, stacklevel=2)
from repro.kernels import stencil_nd
from repro.kernels.stencil_nd.fused import (  # noqa: F401  (re-exported API)
    ORDER,
    stencil7_dot,
    stencil7_two_dots,
)
from repro.kernels.stencil_nd.kernel import stencil_nd_pallas
from repro.kernels.stencil_nd.ops import (  # noqa: F401  (re-exported API)
    VMEM_BUDGET_BYTES,
    pick_zc,
)
from repro.kernels.stencil_nd.ref import stencil_nd_ref


def stencil7_apply(coeffs: StencilCoeffs, v: jax.Array, *,
                   accum_dtype=jnp.float32,
                   interpret: bool | None = None) -> jax.Array:
    """u = A v on a local block (zero-Dirichlet at block edges)."""
    assert v.ndim == 3, "stencil7 kernel is 3D"
    return stencil_nd.stencil_apply(coeffs, v, spec=STAR7,
                                    accum_dtype=accum_dtype,
                                    interpret=interpret)


def stencil7_pallas(v_padded: jax.Array, coeffs: list[jax.Array], *,
                    zc: int, accum_dtype=jnp.float32, interpret: bool = True):
    """v_padded: (bx+2, by+2, Z+2) zero-padded iterate; coeffs: 6 x (bx,by,Z)
    in the order xp, xm, yp, ym, zp, zm (== STAR7.offsets order)."""
    return stencil_nd_pallas(v_padded, coeffs, STAR7.offsets, radius=1,
                             zc=zc, accum_dtype=accum_dtype,
                             interpret=interpret)


def stencil7_ref(v: jax.Array, coeffs: list[jax.Array],
                 accum_dtype=jnp.float32) -> jax.Array:
    """Pure-jnp oracle; coeffs order: xp, xm, yp, ym, zp, zm."""
    return stencil_nd_ref(v, coeffs, STAR7.offsets, accum_dtype=accum_dtype)


def pallas_local_apply(coeffs, v, fabric, *, policy, overlap=None,
                       schedule=None, interpret: bool | None = None):
    """Drop-in for halo.local_apply: halo exchange + fused Pallas SpMV."""
    return stencil_nd.pallas_local_apply(coeffs, v, fabric, policy=policy,
                                         overlap=overlap, schedule=schedule,
                                         interpret=interpret)
