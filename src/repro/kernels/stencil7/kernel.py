"""Fused 7-point stencil SpMV Pallas kernel (the paper's Listing 1, TPU-native).

On the CS-1 the SpMV runs as six SIMD multiply threads feeding FIFO-buffered
add tasks (Fig. 4).  On TPU the idiomatic equivalent is one fused VMEM pass:
the block of the iterate plus its one-point halo is resident in VMEM, the six
off-diagonal products and the unit-diagonal add all happen in registers, and
the result streams back — one read of v, one read of each coefficient
diagonal, one write of u.  No FIFOs, no task scheduler: the XLA/Mosaic
pipeline plays that role.

Tiling: the fabric-local block is (bx, by, Z); Z is split into ``zc`` chunks
(grid dimension) so arbitrary Z fits VMEM.  The halo'd input block is
addressed with ``pl.Element`` so consecutive grid steps read overlapping
(zc+2)-windows of the z-padded iterate — the in-VMEM analogue of the paper's
loopback channel for the z +/- 1 terms.

VMEM per step ~= (bx+2)(by+2)(zc+2) + 7*bx*by*zc halfwords; the ops wrapper
picks zc to stay under the budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vp_ref, xp_ref, xm_ref, yp_ref, ym_ref, zp_ref, zm_ref, u_ref,
            *, accum_dtype):
    vp = vp_ref[...]                       # (bx+2, by+2, zc+2) with halo
    c = lambda a: a.astype(accum_dtype)
    center = vp[1:-1, 1:-1, 1:-1]
    u = c(center)                          # unit main diagonal (preconditioned)
    u += c(xp_ref[...]) * c(vp[2:, 1:-1, 1:-1])
    u += c(xm_ref[...]) * c(vp[:-2, 1:-1, 1:-1])
    u += c(yp_ref[...]) * c(vp[1:-1, 2:, 1:-1])
    u += c(ym_ref[...]) * c(vp[1:-1, :-2, 1:-1])
    u += c(zp_ref[...]) * c(vp[1:-1, 1:-1, 2:])
    u += c(zm_ref[...]) * c(vp[1:-1, 1:-1, :-2])
    u_ref[...] = u.astype(u_ref.dtype)


def stencil7_pallas(v_padded: jax.Array, coeffs: list[jax.Array], *,
                    zc: int, accum_dtype=jnp.float32, interpret: bool = True):
    """v_padded: (bx+2, by+2, Z+2) zero-padded iterate; coeffs: 6 x (bx,by,Z)."""
    bx2, by2, Zp2 = v_padded.shape
    bx, by, Z = bx2 - 2, by2 - 2, Zp2 - 2
    assert Z % zc == 0, (Z, zc)
    grid = (Z // zc,)
    vspec = pl.BlockSpec(
        (pl.Element(bx + 2), pl.Element(by + 2), pl.Element(zc + 2)),
        lambda i: (0, 0, i * zc),
    )
    cspec = pl.BlockSpec((bx, by, zc), lambda i: (0, 0, i))
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype),
        grid=grid,
        in_specs=[vspec] + [cspec] * 6,
        out_specs=cspec,
        out_shape=jax.ShapeDtypeStruct((bx, by, Z), v_padded.dtype),
        interpret=interpret,
    )(v_padded, *coeffs)
