"""7-point stencil SpMV kernel — thin alias of the generalized family kernel.

Historically this module carried its own fused Pallas kernel (the paper's
Listing 1, TPU-native).  That kernel now lives, shape-parameterized, in
:mod:`repro.kernels.stencil_nd`; this wrapper pins the radius-1 star
specialization and the legacy (xp, xm, yp, ym, zp, zm) argument order so
existing callers and tests are untouched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencil import STAR7
from repro.kernels.stencil_nd.kernel import stencil_nd_pallas


def stencil7_pallas(v_padded: jax.Array, coeffs: list[jax.Array], *,
                    zc: int, accum_dtype=jnp.float32, interpret: bool = True):
    """v_padded: (bx+2, by+2, Z+2) zero-padded iterate; coeffs: 6 x (bx,by,Z)
    in the order xp, xm, yp, ym, zp, zm (== STAR7.offsets order)."""
    return stencil_nd_pallas(v_padded, coeffs, STAR7.offsets, radius=1,
                             zc=zc, accum_dtype=accum_dtype, interpret=interpret)
