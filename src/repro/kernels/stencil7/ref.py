"""Pure-jnp oracle for the stencil7 kernel: identical to core.stencil.apply_ref
restricted to a local (zero-Dirichlet) block."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _shift(v, axis, off):
    pad = [(0, 0)] * v.ndim
    if off > 0:
        pad[axis] = (0, off)
        sl = [slice(None)] * v.ndim
        sl[axis] = slice(off, None)
    else:
        pad[axis] = (-off, 0)
        sl = [slice(None)] * v.ndim
        sl[axis] = slice(0, off)
    return jnp.pad(v, pad)[tuple(sl)]


def stencil7_ref(v: jax.Array, coeffs: list[jax.Array],
                 accum_dtype=jnp.float32) -> jax.Array:
    """coeffs order: xp, xm, yp, ym, zp, zm (matches the kernel)."""
    xp, xm, yp, ym, zp, zm = [c.astype(accum_dtype) for c in coeffs]
    vc = v.astype(accum_dtype)
    u = vc
    u = u + xp * _shift(vc, 0, +1)
    u = u + xm * _shift(vc, 0, -1)
    u = u + yp * _shift(vc, 1, +1)
    u = u + ym * _shift(vc, 1, -1)
    u = u + zp * _shift(vc, 2, +1)
    u = u + zm * _shift(vc, 2, -1)
    return u.astype(v.dtype)
