"""7-point wrappers — thin aliases of the generalized stencil_nd package.

``stencil7_apply`` / ``pallas_local_apply`` keep their historical signatures
(they predate the stencil family) and forward to the radius-1 star
specialization of :mod:`repro.kernels.stencil_nd`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stencil import STAR7, StencilCoeffs
from repro.kernels.stencil_nd.ops import (  # noqa: F401  (re-exported API)
    VMEM_BUDGET_BYTES,
    pick_zc,
)
from repro.kernels import stencil_nd

# order must match kernel.py signature (== STAR7.names)
ORDER = ("xp", "xm", "yp", "ym", "zp", "zm")


def stencil7_apply(coeffs: StencilCoeffs, v: jax.Array, *,
                   accum_dtype=jnp.float32, interpret: bool | None = None) -> jax.Array:
    """u = A v on a local block (zero-Dirichlet at block edges)."""
    assert v.ndim == 3, "stencil7 kernel is 3D"
    return stencil_nd.stencil_apply(coeffs, v, spec=STAR7,
                                    accum_dtype=accum_dtype, interpret=interpret)


def pallas_local_apply(coeffs, v, fabric, *, policy, overlap=True,
                       interpret: bool | None = None):
    """Drop-in for halo.local_apply: halo exchange + fused Pallas SpMV."""
    return stencil_nd.pallas_local_apply(coeffs, v, fabric, policy=policy,
                                         overlap=overlap, interpret=interpret)
