"""jit'd wrapper: VMEM budgeting, padding, and the drop-in local-apply that
plugs into the distributed solver (`apply_impl=` of solve_distributed)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.stencil import StencilCoeffs
from repro.kernels.stencil7.kernel import stencil7_pallas

# order must match kernel.py signature
ORDER = ("xp", "xm", "yp", "ym", "zp", "zm")

VMEM_BUDGET_BYTES = 64 * 2 ** 20     # half of a v5e core's ~128MB VMEM


def pick_zc(bx: int, by: int, Z: int, itemsize: int) -> int:
    """Largest Z chunk whose working set fits the VMEM budget."""
    zc = Z
    while zc > 1:
        vmem = ((bx + 2) * (by + 2) * (zc + 2) + 7 * bx * by * zc) * itemsize
        if vmem <= VMEM_BUDGET_BYTES and Z % zc == 0:
            return zc
        zc //= 2
    return 1


@functools.partial(jax.jit, static_argnames=("accum_dtype", "interpret"))
def stencil7_apply(coeffs: StencilCoeffs, v: jax.Array, *,
                   accum_dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    """u = A v on a local block (zero-Dirichlet at block edges)."""
    assert v.ndim == 3, "stencil7 kernel is 3D"
    bx, by, Z = v.shape
    zc = pick_zc(bx, by, Z, jnp.dtype(v.dtype).itemsize)
    vp = jnp.pad(v, ((1, 1), (1, 1), (1, 1)))
    cl = [coeffs.diags[n] for n in ORDER]
    return stencil7_pallas(vp, cl, zc=zc, accum_dtype=accum_dtype,
                           interpret=interpret)


def pallas_local_apply(coeffs, v, fabric, *, policy, overlap=True,
                       interpret: bool = True):
    """Drop-in for halo.local_apply: Pallas interior + face-patch halos.

    The kernel computes the zero-Dirichlet interior contribution; the four
    (or six, multi-pod) received faces each patch one boundary plane — the
    same decomposition halo.local_apply uses with overlap=True.
    """
    from repro.core.halo import halo_faces, _AXIS_OF, _SIGN_OF

    faces = halo_faces(v, fabric)
    u = stencil7_apply(coeffs.astype(policy.storage), v.astype(policy.storage),
                       accum_dtype=policy.compute, interpret=interpret)
    c = policy.compute
    u = u.astype(c)
    for name, face in faces.items():
        ax, sign = _AXIS_OF[name], _SIGN_OF[name]
        sl = tuple(
            (slice(-1, None) if sign > 0 else slice(0, 1)) if i == ax else slice(None)
            for i in range(v.ndim)
        )
        u = u.at[sl].add(coeffs.diags[name][sl].astype(c) * face.astype(c))
    return u.astype(policy.storage)
