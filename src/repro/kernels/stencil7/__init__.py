from repro.kernels.stencil7.ops import stencil7_apply  # noqa: F401
from repro.kernels.stencil7.ref import stencil7_ref  # noqa: F401
