from repro.kernels.fused_iter.ops import (  # noqa: F401
    dot_mixed, update_p, update_q_dots, update_xr_dots,
)
from repro.kernels.fused_iter import ref  # noqa: F401
