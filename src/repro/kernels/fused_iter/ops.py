"""jit'd wrappers: flatten the mesh block to (rows, 128), pad, dispatch, and
reshape back.  Zero padding is exact for every fused op (pads contribute 0 to
dots and are sliced off the vector outputs)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import resolve_interpret

LANES = 128
DEFAULT_BM = 512


def _to_rows(a: jax.Array):
    n = a.size
    rows = -(-n // LANES)
    bm = min(DEFAULT_BM, rows)
    rows_pad = -(-rows // bm) * bm
    flat = jnp.pad(a.reshape(-1), (0, rows_pad * LANES - n))
    return flat.reshape(rows_pad, LANES), bm


def _like(flat: jax.Array, a: jax.Array):
    return flat.reshape(-1)[: a.size].reshape(a.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def update_q_dots(alpha, r, s, y, *, interpret: bool | None = None):
    from repro.kernels.fused_iter.kernel import update_q_dots_pallas
    interpret = resolve_interpret(interpret)
    r2, bm = _to_rows(r)
    s2, _ = _to_rows(s)
    y2, _ = _to_rows(y)
    q2, qy, yy = update_q_dots_pallas(jnp.asarray(alpha), r2, s2, y2,
                                      bm=bm, interpret=interpret)
    return _like(q2, r), qy[0, 0], yy[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def update_xr_dots(alpha, omega, x, p, q, y, r0, *, interpret: bool | None = None):
    from repro.kernels.fused_iter.kernel import update_xr_dots_pallas
    interpret = resolve_interpret(interpret)
    arrs = [_to_rows(a)[0] for a in (x, p, q, y, r0)]
    bm = _to_rows(x)[1]
    xo, ro, r0r, rr = update_xr_dots_pallas(
        jnp.asarray(alpha), jnp.asarray(omega), *arrs, bm=bm, interpret=interpret)
    return _like(xo, x), _like(ro, x), r0r[0, 0], rr[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def update_p(beta, omega, r, p, s, *, interpret: bool | None = None):
    from repro.kernels.fused_iter.kernel import update_p_pallas
    interpret = resolve_interpret(interpret)
    r2, bm = _to_rows(r)
    p2, _ = _to_rows(p)
    s2, _ = _to_rows(s)
    po = update_p_pallas(jnp.asarray(beta), jnp.asarray(omega), r2, p2, s2,
                         bm=bm, interpret=interpret)
    return _like(po, r)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot_mixed(a, b, *, interpret: bool | None = None):
    from repro.kernels.fused_iter.kernel import dot_mixed_pallas
    interpret = resolve_interpret(interpret)
    a2, bm = _to_rows(a)
    b2, _ = _to_rows(b)
    return dot_mixed_pallas(a2, b2, bm=bm, interpret=interpret)[0, 0]
