"""jit'd wrappers: flatten the mesh block to (rows, 128), pad, dispatch, and
reshape back.  Zero padding is exact for every fused op (pads contribute 0 to
dots and are sliced off the vector outputs).

``batched=True`` flattens each RHS of a ``(B, mesh...)`` operand to its own
(rows, 128) plane — the per-RHS row layout, padding, and block size are
exactly the unbatched ones — and returns per-RHS ``[B]`` scalars for the dot
partials (the solver stacks one sync point's partials into a single ``[k, B]``
AllReduce)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import resolve_interpret

LANES = 128
DEFAULT_BM = 512


def _to_rows(a: jax.Array, n_batch: int = 0):
    if n_batch:
        B = a.shape[0]
        n = a.size // B
        rows = -(-n // LANES)
        bm = min(DEFAULT_BM, rows)
        rows_pad = -(-rows // bm) * bm
        flat = jnp.pad(a.reshape(B, -1), ((0, 0), (0, rows_pad * LANES - n)))
        return flat.reshape(B, rows_pad, LANES), bm
    n = a.size
    rows = -(-n // LANES)
    bm = min(DEFAULT_BM, rows)
    rows_pad = -(-rows // bm) * bm
    flat = jnp.pad(a.reshape(-1), (0, rows_pad * LANES - n))
    return flat.reshape(rows_pad, LANES), bm


def _like(flat: jax.Array, a: jax.Array, n_batch: int = 0):
    if n_batch:
        B = a.shape[0]
        return flat.reshape(B, -1)[:, : a.size // B].reshape(a.shape)
    return flat.reshape(-1)[: a.size].reshape(a.shape)


@functools.partial(jax.jit, static_argnames=("interpret", "batched"))
def update_q_dots(alpha, r, s, y, *, interpret: bool | None = None,
                  batched: bool = False):
    from repro.kernels.fused_iter.kernel import update_q_dots_pallas
    interpret = resolve_interpret(interpret)
    nb = 1 if batched else 0
    r2, bm = _to_rows(r, nb)
    s2, _ = _to_rows(s, nb)
    y2, _ = _to_rows(y, nb)
    q2, qy, yy = update_q_dots_pallas(jnp.asarray(alpha), r2, s2, y2,
                                      bm=bm, interpret=interpret,
                                      batched=batched)
    if batched:
        return _like(q2, r, nb), qy[:, 0], yy[:, 0]
    return _like(q2, r), qy[0, 0], yy[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret", "batched"))
def update_xr_dots(alpha, omega, x, p, q, y, r0, *,
                   interpret: bool | None = None, batched: bool = False):
    from repro.kernels.fused_iter.kernel import update_xr_dots_pallas
    interpret = resolve_interpret(interpret)
    nb = 1 if batched else 0
    arrs = [_to_rows(a, nb)[0] for a in (x, p, q, y, r0)]
    bm = _to_rows(x, nb)[1]
    xo, ro, r0r, rr = update_xr_dots_pallas(
        jnp.asarray(alpha), jnp.asarray(omega), *arrs, bm=bm,
        interpret=interpret, batched=batched)
    if batched:
        return _like(xo, x, nb), _like(ro, x, nb), r0r[:, 0], rr[:, 0]
    return _like(xo, x), _like(ro, x), r0r[0, 0], rr[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret", "batched"))
def update_p(beta, omega, r, p, s, *, interpret: bool | None = None,
             batched: bool = False):
    from repro.kernels.fused_iter.kernel import update_p_pallas
    interpret = resolve_interpret(interpret)
    nb = 1 if batched else 0
    r2, bm = _to_rows(r, nb)
    p2, _ = _to_rows(p, nb)
    s2, _ = _to_rows(s, nb)
    po = update_p_pallas(jnp.asarray(beta), jnp.asarray(omega), r2, p2, s2,
                         bm=bm, interpret=interpret, batched=batched)
    return _like(po, r, nb)


@functools.partial(jax.jit, static_argnames=("interpret", "batched"))
def dot_mixed(a, b, *, interpret: bool | None = None, batched: bool = False):
    from repro.kernels.fused_iter.kernel import dot_mixed_pallas
    interpret = resolve_interpret(interpret)
    nb = 1 if batched else 0
    a2, bm = _to_rows(a, nb)
    b2, _ = _to_rows(b, nb)
    out = dot_mixed_pallas(a2, b2, bm=bm, interpret=interpret, batched=batched)
    return out[:, 0] if batched else out[0, 0]
