"""Pure-jnp oracles for the fused_iter kernels (paper FMAC semantics:
storage-dtype elementwise ops, f32 dot accumulation)."""

from __future__ import annotations

import jax.numpy as jnp


def _dot(a, b):
    return jnp.sum((a * b).astype(jnp.float32))


def update_q_dots_ref(alpha, r, s, y):
    q = r - alpha.astype(r.dtype) * s
    return q, _dot(q, y), _dot(y, y)


def update_xr_dots_ref(alpha, omega, x, p, q, y, r0):
    a, w = alpha.astype(x.dtype), omega.astype(x.dtype)
    x_new = x + a * p + w * q
    r_new = q - w * y
    return x_new, r_new, _dot(r0, r_new), _dot(r_new, r_new)


def update_p_ref(beta, omega, r, p, s):
    b, w = beta.astype(p.dtype), omega.astype(p.dtype)
    return r + b * (p - w * s)


def dot_mixed_ref(a, b):
    return _dot(a, b)
