"""Fused BiCGStab vector-update + inner-product Pallas kernels.

The paper's iteration sweeps the per-core state ~13 times (2 SpMV reads x 8
vectors, 6 AXPYs, 4 dots).  On TPU the memory roofline term is exactly
proportional to those sweeps, so we fuse each "update then dot" pair into a
single pass (the CS-1 analogue: its AXPYs and dot products were separate
tensor instructions but all operands already lived in SRAM; on TPU the state
lives in HBM and fusion is where the paper's SRAM-residency advantage must be
re-earned — DESIGN.md §2).

All kernels run on a (rows, 128)-tiled flattening of the mesh block with
f32 scalar accumulators carried across sequential grid steps (TPU grid
iterations execute in order, so += into a (1,1) output block is sound; same
semantics in interpret mode).

Batched (many-RHS) form: every wrapper takes ``batched=True`` and then works
on a ``(B, rows, 128)`` tiling with grid ``(B, rows // bm)`` — the row-sweep
axis moves to grid position 1 (``seq_axis``), the per-RHS scalars ride in
``(B, 1)``/``(B, 2)`` blocks indexed by the batch coordinate, and each RHS
accumulates its own f32 partial into its own ``(1, 1)`` output block.  Per
RHS the arithmetic (tile shapes, sweep order, accumulation order) is
identical to the unbatched form, so B=1 is bitwise equal.

Precision: products in the storage dtype (bf16), accumulation in f32 — the
paper's FMAC discipline (Table I mixed column).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_spec(bm):
    return pl.BlockSpec((bm, 128), lambda i: (i, 0))


def _scalar_spec():
    return pl.BlockSpec((1, 1), lambda i: (0, 0))


def _row_spec_b(bm):
    return pl.BlockSpec((1, bm, 128), lambda b, i: (b, i, 0))


def _scalar_spec_b(width: int = 1):
    return pl.BlockSpec((1, width), lambda b, i: (b, 0))


def _acc_init(i, *refs):
    @pl.when(i == 0)
    def _():
        for r in refs:
            r[...] = jnp.zeros_like(r)


# --- q = r - alpha*s ; partials <q,y>, <y,y> ------------------------------

def _update_q_kernel(alpha_ref, r_ref, s_ref, y_ref, q_ref, qy_ref, yy_ref,
                     *, seq_axis=0):
    i = pl.program_id(seq_axis)
    _acc_init(i, qy_ref, yy_ref)
    alpha = alpha_ref[0, 0]
    q = r_ref[...] - (alpha.astype(r_ref.dtype) * s_ref[...])
    q_ref[...] = q
    yf = y_ref[...].astype(jnp.float32)
    qy_ref[...] += jnp.sum(q.astype(jnp.float32) * yf).reshape(1, 1)
    yy_ref[...] += jnp.sum(yf * yf).reshape(1, 1)


def update_q_dots_pallas(alpha, r, s, y, *, bm: int, interpret: bool = True,
                         batched: bool = False):
    if batched:
        B, M = r.shape[0], r.shape[1]
        row, sca = _row_spec_b(bm), _scalar_spec_b()
        return pl.pallas_call(
            functools.partial(_update_q_kernel, seq_axis=1),
            grid=(B, M // bm),
            in_specs=[sca, row, row, row],
            out_specs=[row, sca, sca],
            out_shape=[
                jax.ShapeDtypeStruct(r.shape, r.dtype),
                jax.ShapeDtypeStruct((B, 1), jnp.float32),
                jax.ShapeDtypeStruct((B, 1), jnp.float32),
            ],
            interpret=interpret,
        )(alpha.reshape(B, 1).astype(jnp.float32), r, s, y)
    M = r.shape[0]
    grid = (M // bm,)
    return pl.pallas_call(
        _update_q_kernel,
        grid=grid,
        in_specs=[_scalar_spec(), _row_spec(bm), _row_spec(bm), _row_spec(bm)],
        out_specs=[_row_spec(bm), _scalar_spec(), _scalar_spec()],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha.reshape(1, 1).astype(jnp.float32), r, s, y)


# --- x += alpha*p + omega*q ; r = q - omega*y ; <r0,r>, <r,r> --------------

def _update_xr_kernel(ab_ref, x_ref, p_ref, q_ref, y_ref, r0_ref,
                      xo_ref, ro_ref, r0r_ref, rr_ref, *, seq_axis=0):
    i = pl.program_id(seq_axis)
    _acc_init(i, r0r_ref, rr_ref)
    alpha = ab_ref[0, 0].astype(x_ref.dtype)
    omega = ab_ref[0, 1].astype(x_ref.dtype)
    q = q_ref[...]
    xo_ref[...] = x_ref[...] + alpha * p_ref[...] + omega * q
    r = q - omega * y_ref[...]
    ro_ref[...] = r
    rf = r.astype(jnp.float32)
    r0r_ref[...] += jnp.sum(r0_ref[...].astype(jnp.float32) * rf).reshape(1, 1)
    rr_ref[...] += jnp.sum(rf * rf).reshape(1, 1)


def update_xr_dots_pallas(alpha, omega, x, p, q, y, r0, *, bm: int,
                          interpret: bool = True, batched: bool = False):
    if batched:
        B, M = x.shape[0], x.shape[1]
        ab = jnp.stack([alpha, omega], axis=-1).astype(jnp.float32)  # (B, 2)
        row = _row_spec_b(bm)
        return pl.pallas_call(
            functools.partial(_update_xr_kernel, seq_axis=1),
            grid=(B, M // bm),
            in_specs=[_scalar_spec_b(2)] + [row] * 5,
            out_specs=[row, row, _scalar_spec_b(), _scalar_spec_b()],
            out_shape=[
                jax.ShapeDtypeStruct(x.shape, x.dtype),
                jax.ShapeDtypeStruct(x.shape, x.dtype),
                jax.ShapeDtypeStruct((B, 1), jnp.float32),
                jax.ShapeDtypeStruct((B, 1), jnp.float32),
            ],
            interpret=interpret,
        )(ab, x, p, q, y, r0)
    M = x.shape[0]
    ab = jnp.stack([alpha, omega]).reshape(1, 2).astype(jnp.float32)
    return pl.pallas_call(
        _update_xr_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))] + [_row_spec(bm)] * 5,
        out_specs=[_row_spec(bm), _row_spec(bm), _scalar_spec(), _scalar_spec()],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(ab, x, p, q, y, r0)


# --- p = r + beta*(p - omega*s) -------------------------------------------

def _update_p_kernel(bo_ref, r_ref, p_ref, s_ref, po_ref):
    beta = bo_ref[0, 0].astype(p_ref.dtype)
    omega = bo_ref[0, 1].astype(p_ref.dtype)
    po_ref[...] = r_ref[...] + beta * (p_ref[...] - omega * s_ref[...])


def update_p_pallas(beta, omega, r, p, s, *, bm: int, interpret: bool = True,
                    batched: bool = False):
    if batched:
        B, M = r.shape[0], r.shape[1]
        bo = jnp.stack([beta, omega], axis=-1).astype(jnp.float32)   # (B, 2)
        row = _row_spec_b(bm)
        return pl.pallas_call(
            _update_p_kernel,
            grid=(B, M // bm),
            in_specs=[_scalar_spec_b(2)] + [row] * 3,
            out_specs=row,
            out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
            interpret=interpret,
        )(bo, r, p, s)
    M = r.shape[0]
    bo = jnp.stack([beta, omega]).reshape(1, 2).astype(jnp.float32)
    return pl.pallas_call(
        _update_p_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0))] + [_row_spec(bm)] * 3,
        out_specs=_row_spec(bm),
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        interpret=interpret,
    )(bo, r, p, s)


# --- plain mixed-precision dot --------------------------------------------

def _dot_kernel(a_ref, b_ref, o_ref, *, seq_axis=0):
    i = pl.program_id(seq_axis)
    _acc_init(i, o_ref)
    prod = (a_ref[...] * b_ref[...]).astype(jnp.float32)   # bf16 multiply, f32 add
    o_ref[...] += jnp.sum(prod).reshape(1, 1)


def dot_mixed_pallas(a, b, *, bm: int, interpret: bool = True,
                     batched: bool = False):
    if batched:
        B, M = a.shape[0], a.shape[1]
        row = _row_spec_b(bm)
        return pl.pallas_call(
            functools.partial(_dot_kernel, seq_axis=1),
            grid=(B, M // bm),
            in_specs=[row, row],
            out_specs=_scalar_spec_b(),
            out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
            interpret=interpret,
        )(a, b)
    M = a.shape[0]
    return pl.pallas_call(
        _dot_kernel,
        grid=(M // bm,),
        in_specs=[_row_spec(bm), _row_spec(bm)],
        out_specs=_scalar_spec(),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(a, b)
