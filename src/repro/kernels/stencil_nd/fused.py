"""Fused kernel epilogues: the boundary-ring fold for the overlap
schedule, and the 7-point SpMV inner-product epilogues (EXPERIMENTS.md
§Perf, stencil v3).

**Boundary-ring epilogue** (:func:`fused_ring_apply`): the overlap
schedule's split form pays one interior kernel launch plus one patch
launch per boundary region; the fused form folds the ring into the
interior kernel's own pass — one launch per overlapped SpMV.  Selection is
per-cell via the tuning cache (``KernelConfig.fuse_ring``), because the
fold is a genuine trade: it removes the extra launches and the ring
re-reads, but the single pass now reads the *exchanged* block, so the
whole kernel depends on the halo collectives instead of only the depth-r
ring — on fabrics where halo latency is fully hidden anyway (the paper's
regime) fusion wins; where the interior must cover the transfers the split
form wins.  The sweep decides.

**Dot epilogues**: two variants used by the BiCGStab iteration:
  * ``stencil7_dot``      : s = A p  and  <r0, s>       (sync point 1 feed)
  * ``stencil7_two_dots`` : y = A q  and  <q, y>, <y, y> (sync point 2 feed)

Fusing the dot into the SpMV's write-out pass removes a full re-read of the
freshly written vector (and of the second operand), cutting the iteration's
per-point traffic from 42 to 31 words (see kernels/fused_iter for the AXPY
fusions).  Dots accumulate in f32 across sequential grid steps (paper FMAC
discipline).

The dot epilogues are the one radius-1-star specialization left in the
package (the ``kernels/stencil7`` shim re-exports them under their
historical home); the ring epilogue is generic over the stencil family.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import HAS_PL_ELEMENT, resolve_interpret
from repro.core.stencil import STAR7, StencilCoeffs
from repro.kernels.stencil_nd.ops import pick_zc

# kernel argument order (== STAR7.names: xp, xm, yp, ym, zp, zm)
ORDER = STAR7.names


def fused_ring_apply(exchange, cf_list: list[jax.Array], spec, config, *,
                     accum_dtype=jnp.float32,
                     interpret: bool | None = None) -> jax.Array:
    """One-launch overlapped SpMV: interior + boundary ring in one pass.

    Runs the fused stencil kernel once over the *exchanged* r-padded block.
    Bitwise identity with the split interior+ring form follows from the
    kernel's per-element contract: a non-ring cell never reads halo values,
    so its sum is unchanged between the zero-padded and exchanged inputs;
    a ring cell computes exactly the canonical-order sum the split form's
    patch kernel computes from the same exchanged slabs.  Tiling cannot
    break this — each output element is an independent canonical-order
    accumulation, whatever the grid decomposition (asserted bitwise across
    schedules and epilogues in tests/test_tuning.py).

    Launch accounting: this is 1 pallas_call per SpMV where the split form
    traces 1 + (patch launches per split boundary region).
    """
    from repro.kernels.stencil_nd.ops import tile_apply

    assert exchange.radius == spec.radius, (exchange.radius, spec.radius)
    return tile_apply(exchange.padded, cf_list, spec, config,
                      accum_dtype=accum_dtype, interpret=interpret)


def _kernel(vp_ref, w_ref, xp_ref, xm_ref, yp_ref, ym_ref, zp_ref, zm_ref,
            u_ref, d1_ref, d2_ref, *, accum_dtype, two_dots, block, zc):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        d1_ref[...] = jnp.zeros_like(d1_ref)
        d2_ref[...] = jnp.zeros_like(d2_ref)

    vp = vp_ref[...]
    if not HAS_PL_ELEMENT:
        # padded iterate fully resident: cut this step's z-window by hand
        bx, by = block
        vp = jax.lax.dynamic_slice(vp, (0, 0, i * zc), (bx + 2, by + 2, zc + 2))
    c = lambda a: a.astype(accum_dtype)
    u = c(vp[1:-1, 1:-1, 1:-1])
    u += c(xp_ref[...]) * c(vp[2:, 1:-1, 1:-1])
    u += c(xm_ref[...]) * c(vp[:-2, 1:-1, 1:-1])
    u += c(yp_ref[...]) * c(vp[1:-1, 2:, 1:-1])
    u += c(ym_ref[...]) * c(vp[1:-1, :-2, 1:-1])
    u += c(zp_ref[...]) * c(vp[1:-1, 1:-1, 2:])
    u += c(zm_ref[...]) * c(vp[1:-1, 1:-1, :-2])
    u_ref[...] = u.astype(u_ref.dtype)
    # epilogue: dots against w (= r0 or q) and optionally u itself, in f32
    uf = u.astype(jnp.float32)
    wf = w_ref[...].astype(jnp.float32)
    d1_ref[...] += jnp.sum(wf * uf).reshape(1, 1)
    if two_dots:
        d2_ref[...] += jnp.sum(uf * uf).reshape(1, 1)


def _call(coeffs: StencilCoeffs, v: jax.Array, w: jax.Array, *, two_dots: bool,
          accum_dtype=jnp.float32, interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    bx, by, Z = v.shape
    zc = pick_zc(bx, by, Z, jnp.dtype(v.dtype).itemsize)
    vp = jnp.pad(v, ((1, 1), (1, 1), (1, 1)))
    if HAS_PL_ELEMENT:
        vspec = pl.BlockSpec(
            (pl.Element(bx + 2), pl.Element(by + 2), pl.Element(zc + 2)),
            lambda i: (0, 0, i * zc))
    else:
        vspec = pl.BlockSpec(vp.shape, lambda i: (0, 0, 0))
    cspec = pl.BlockSpec((bx, by, zc), lambda i: (0, 0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    u, d1, d2 = pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype, two_dots=two_dots,
                          block=(bx, by), zc=zc),
        grid=(Z // zc,),
        in_specs=[vspec, cspec] + [cspec] * 6,
        out_specs=[cspec, sspec, sspec],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, Z), v.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(vp, w, *[coeffs.diags[n] for n in ORDER])
    return u, d1[0, 0], d2[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def stencil7_dot(coeffs: StencilCoeffs, p: jax.Array, r0: jax.Array, *,
                 interpret: bool | None = None):
    """s = A p, <r0, s> in one pass. Returns (s, r0s_partial)."""
    s, d1, _ = _call(coeffs, p, r0, two_dots=False, interpret=interpret)
    return s, d1


@functools.partial(jax.jit, static_argnames=("interpret",))
def stencil7_two_dots(coeffs: StencilCoeffs, q: jax.Array, *,
                      interpret: bool | None = None):
    """y = A q, <q, y>, <y, y> in one pass. Returns (y, qy, yy)."""
    y, qy, yy = _call(coeffs, q, q, two_dots=True, interpret=interpret)
    return y, qy, yy
