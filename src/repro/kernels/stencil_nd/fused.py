"""7-point SpMV with fused inner-product epilogues — the remaining pieces
of the fused-iteration schedule (EXPERIMENTS.md §Perf, stencil v3).

Two variants used by the BiCGStab iteration:
  * ``stencil7_dot``      : s = A p  and  <r0, s>       (sync point 1 feed)
  * ``stencil7_two_dots`` : y = A q  and  <q, y>, <y, y> (sync point 2 feed)

Fusing the dot into the SpMV's write-out pass removes a full re-read of the
freshly written vector (and of the second operand), cutting the iteration's
per-point traffic from 42 to 31 words (see kernels/fused_iter for the AXPY
fusions).  Dots accumulate in f32 across sequential grid steps (paper FMAC
discipline).

This module is the one radius-1-star specialization left in the package:
the dot epilogues are only wired for the paper's 7-point shape (the
``kernels/stencil7`` shim re-exports them under their historical home).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import HAS_PL_ELEMENT, resolve_interpret
from repro.core.stencil import STAR7, StencilCoeffs
from repro.kernels.stencil_nd.ops import pick_zc

# kernel argument order (== STAR7.names: xp, xm, yp, ym, zp, zm)
ORDER = STAR7.names


def _kernel(vp_ref, w_ref, xp_ref, xm_ref, yp_ref, ym_ref, zp_ref, zm_ref,
            u_ref, d1_ref, d2_ref, *, accum_dtype, two_dots, block, zc):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        d1_ref[...] = jnp.zeros_like(d1_ref)
        d2_ref[...] = jnp.zeros_like(d2_ref)

    vp = vp_ref[...]
    if not HAS_PL_ELEMENT:
        # padded iterate fully resident: cut this step's z-window by hand
        bx, by = block
        vp = jax.lax.dynamic_slice(vp, (0, 0, i * zc), (bx + 2, by + 2, zc + 2))
    c = lambda a: a.astype(accum_dtype)
    u = c(vp[1:-1, 1:-1, 1:-1])
    u += c(xp_ref[...]) * c(vp[2:, 1:-1, 1:-1])
    u += c(xm_ref[...]) * c(vp[:-2, 1:-1, 1:-1])
    u += c(yp_ref[...]) * c(vp[1:-1, 2:, 1:-1])
    u += c(ym_ref[...]) * c(vp[1:-1, :-2, 1:-1])
    u += c(zp_ref[...]) * c(vp[1:-1, 1:-1, 2:])
    u += c(zm_ref[...]) * c(vp[1:-1, 1:-1, :-2])
    u_ref[...] = u.astype(u_ref.dtype)
    # epilogue: dots against w (= r0 or q) and optionally u itself, in f32
    uf = u.astype(jnp.float32)
    wf = w_ref[...].astype(jnp.float32)
    d1_ref[...] += jnp.sum(wf * uf).reshape(1, 1)
    if two_dots:
        d2_ref[...] += jnp.sum(uf * uf).reshape(1, 1)


def _call(coeffs: StencilCoeffs, v: jax.Array, w: jax.Array, *, two_dots: bool,
          accum_dtype=jnp.float32, interpret: bool | None = None):
    interpret = resolve_interpret(interpret)
    bx, by, Z = v.shape
    zc = pick_zc(bx, by, Z, jnp.dtype(v.dtype).itemsize)
    vp = jnp.pad(v, ((1, 1), (1, 1), (1, 1)))
    if HAS_PL_ELEMENT:
        vspec = pl.BlockSpec(
            (pl.Element(bx + 2), pl.Element(by + 2), pl.Element(zc + 2)),
            lambda i: (0, 0, i * zc))
    else:
        vspec = pl.BlockSpec(vp.shape, lambda i: (0, 0, 0))
    cspec = pl.BlockSpec((bx, by, zc), lambda i: (0, 0, i))
    sspec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    u, d1, d2 = pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype, two_dots=two_dots,
                          block=(bx, by), zc=zc),
        grid=(Z // zc,),
        in_specs=[vspec, cspec] + [cspec] * 6,
        out_specs=[cspec, sspec, sspec],
        out_shape=[
            jax.ShapeDtypeStruct((bx, by, Z), v.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(vp, w, *[coeffs.diags[n] for n in ORDER])
    return u, d1[0, 0], d2[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def stencil7_dot(coeffs: StencilCoeffs, p: jax.Array, r0: jax.Array, *,
                 interpret: bool | None = None):
    """s = A p, <r0, s> in one pass. Returns (s, r0s_partial)."""
    s, d1, _ = _call(coeffs, p, r0, two_dots=False, interpret=interpret)
    return s, d1


@functools.partial(jax.jit, static_argnames=("interpret",))
def stencil7_two_dots(coeffs: StencilCoeffs, q: jax.Array, *,
                      interpret: bool | None = None):
    """y = A q, <q, y>, <y, y> in one pass. Returns (y, qy, yy)."""
    y, qy, yy = _call(coeffs, q, q, two_dots=True, interpret=interpret)
    return y, qy, yy
