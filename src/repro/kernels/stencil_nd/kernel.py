"""Generalized fused stencil SpMV Pallas kernel — any spec in the family.

The 7-point kernel (kernels/stencil7) lowers the paper's Listing 1 to one
fused VMEM pass.  This package lowers *any* :class:`~repro.core.stencil
.StencilSpec` the same way: the local block of the iterate plus its
radius-r halo is resident in VMEM, every off-diagonal product reads a
statically shifted (r,r,r)-halo'd window of that block, and the accumulated
result streams back — one read of v, one read of each coefficient diagonal,
one write of u, for 7, 13, 25 or 27 points alike.

Tiling follows stencil7: the fabric-local block is (bx, by, Z); Z is split
into ``zc`` chunks (grid dimension) so arbitrary Z fits VMEM.  With
element-indexed BlockSpecs (``pl.Element``) consecutive grid steps read
overlapping (zc+2r)-windows of the z-padded iterate — the in-VMEM analogue
of the paper's loopback channel, now r planes deep.  On jax versions
without ``pl.Element`` the padded iterate stays fully resident and the
window is cut with ``lax.dynamic_slice`` inside the kernel body instead
(see repro.compat.HAS_PL_ELEMENT).

VMEM per step ~= (bx+2r)(by+2r)(zc+2r) + (n_offsets+1)*bx*by*zc halfwords;
the ops wrapper picks zc to stay under the budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import HAS_PL_ELEMENT


def _kernel(vp_ref, *refs, offsets, radius, block, zc, accum_dtype, resident):
    cf_refs, u_ref = refs[:-1], refs[-1]
    bx, by, _ = block
    r = radius
    vp = vp_ref[...]
    if resident:
        # whole padded array resident: cut this step's z-window by hand
        i = pl.program_id(0)
        vp = jax.lax.dynamic_slice(
            vp, (0, 0, i * zc), (bx + 2 * r, by + 2 * r, zc + 2 * r))
    c = lambda a: a.astype(accum_dtype)
    win = lambda off: vp[r + off[0]:r + off[0] + bx,
                         r + off[1]:r + off[1] + by,
                         r + off[2]:r + off[2] + zc]
    u = c(win((0, 0, 0)))        # unit main diagonal (Jacobi preconditioned)
    for cf_ref, off in zip(cf_refs, offsets):
        u += c(cf_ref[...]) * c(win(off))
    u_ref[...] = u.astype(u_ref.dtype)


def stencil_nd_pallas(v_padded: jax.Array, coeffs: list[jax.Array],
                      offsets: tuple[tuple[int, int, int], ...], *,
                      radius: int, zc: int, accum_dtype=jnp.float32,
                      interpret: bool = True):
    """u = A v on one local block.

    ``v_padded``: (bx+2r, by+2r, Z+2r) iterate with halo (zero-padded for a
    standalone block, fabric-filled by ``core.halo.gather_halo`` inside the
    distributed solver).  ``coeffs[i]`` is the (bx, by, Z) diagonal that
    multiplies the ``offsets[i]``-shifted window.
    """
    r = radius
    bx, by, Z = (s - 2 * r for s in v_padded.shape)
    assert Z % zc == 0, (Z, zc)
    grid = (Z // zc,)
    if HAS_PL_ELEMENT:
        vspec = pl.BlockSpec(
            (pl.Element(bx + 2 * r), pl.Element(by + 2 * r), pl.Element(zc + 2 * r)),
            lambda i: (0, 0, i * zc),
        )
    else:
        vspec = pl.BlockSpec(v_padded.shape, lambda i: (0, 0, 0))
    cspec = pl.BlockSpec((bx, by, zc), lambda i: (0, 0, i))
    return pl.pallas_call(
        functools.partial(
            _kernel, offsets=tuple(offsets), radius=r, block=(bx, by, Z),
            zc=zc, accum_dtype=accum_dtype, resident=not HAS_PL_ELEMENT),
        grid=grid,
        in_specs=[vspec] + [cspec] * len(coeffs),
        out_specs=cspec,
        out_shape=jax.ShapeDtypeStruct((bx, by, Z), v_padded.dtype),
        interpret=interpret,
    )(v_padded, *coeffs)
