"""Generalized fused stencil SpMV Pallas kernel — any spec in the family.

The 7-point kernel (kernels/stencil7) lowers the paper's Listing 1 to one
fused VMEM pass.  This package lowers *any* :class:`~repro.core.stencil
.StencilSpec` the same way: the local block of the iterate plus its
radius-r halo is resident in VMEM, every off-diagonal product reads a
statically shifted (r,r,r)-halo'd window of that block, and the accumulated
result streams back — one read of v, one read of each coefficient diagonal,
one write of u, for 7, 13, 25 or 27 points alike.

Tiling is the kernel's tuning space (``core/tuning.KernelConfig``): the
fabric-local block is cut into a ``(bxc, byc, zc)`` tile grid.  The paper's
layout is the degenerate full-block tile with Z split into chunks so
arbitrary Z fits VMEM; the autotuner (``benchmarks/kernel_autotune.py``)
sweeps the x/y tiles and Z-split factors per {spec x dtype x local shape}
and persists winners to the tuning cache.  With element-indexed BlockSpecs
(``pl.Element``) consecutive grid steps read overlapping halo'd windows of
the padded iterate — the in-VMEM analogue of the paper's loopback channel,
r planes deep.  On jax versions without ``pl.Element`` the padded iterate
stays fully resident and the window is cut with ``lax.dynamic_slice``
inside the kernel body instead (see repro.compat.HAS_PL_ELEMENT) — the
``resident`` VMEM choice the tuner also sweeps where both forms exist.

Tile shapes that do not evenly divide the local block (e.g. the paper's
unpadded 600 x 595 tiles) are clamped at trace time to the nearest valid
divisors with a warning — never left to surface as a cryptic Pallas
BlockSpec error.

VMEM per step ~= (bxc+2r)(byc+2r)(zc+2r) + (n_offsets+1)*bxc*byc*zc
halfwords; the ops wrapper picks the chunking to stay under the budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import HAS_PL_ELEMENT
from repro.obs import metrics as obs_metrics

# Count of pallas_call ops traced for the stencil SpMV — the kernel-launch
# accounting behind the fused boundary-ring epilogue's 2 -> 1 claim (each
# traced call is one kernel op in the lowered program).  Tests snapshot it
# around a traced apply; see tests/test_tuning.py.  Mirrored into the
# observability registry as ``kernels.stencil_nd.traced_calls``.
_TRACED_CALLS = 0


def traced_call_count() -> int:
    """Total stencil pallas_call ops traced so far in this process."""
    return _TRACED_CALLS


def _kernel(vp_ref, *refs, offsets, radius, tile, accum_dtype, resident):
    cf_refs, u_ref = refs[:-1], refs[-1]
    bxc, byc, zc = tile
    r = radius
    vp = vp_ref[...]
    if resident:
        # whole padded array resident: cut this step's tile window by hand
        i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        vp = jax.lax.dynamic_slice(
            vp, (i * bxc, j * byc, k * zc),
            (bxc + 2 * r, byc + 2 * r, zc + 2 * r))
    c = lambda a: a.astype(accum_dtype)
    win = lambda off: vp[r + off[0]:r + off[0] + bxc,
                         r + off[1]:r + off[1] + byc,
                         r + off[2]:r + off[2] + zc]
    u = c(win((0, 0, 0)))        # unit main diagonal (Jacobi preconditioned)
    for cf_ref, off in zip(cf_refs, offsets):
        u += c(cf_ref[...]) * c(win(off))
    u_ref[...] = u.astype(u_ref.dtype)


def _kernel_batched(vp_ref, *refs, offsets, radius, tile, accum_dtype):
    """Batched (many-RHS) body: grid is (B, gx, gy, gz); each step works on
    one RHS's tile window, with the coefficient tiles shared across the
    batch axis (their BlockSpec ignores the batch index).  Arithmetic per
    RHS is identical to :func:`_kernel`'s resident path — same window cuts,
    same accumulation order — so B=1 is bitwise equal to the unbatched
    kernel."""
    cf_refs, u_ref = refs[:-1], refs[-1]
    bxc, byc, zc = tile
    r = radius
    vp = vp_ref[0]               # this RHS's whole padded block
    i, j, k = pl.program_id(1), pl.program_id(2), pl.program_id(3)
    vp = jax.lax.dynamic_slice(
        vp, (i * bxc, j * byc, k * zc),
        (bxc + 2 * r, byc + 2 * r, zc + 2 * r))
    c = lambda a: a.astype(accum_dtype)
    win = lambda off: vp[r + off[0]:r + off[0] + bxc,
                         r + off[1]:r + off[1] + byc,
                         r + off[2]:r + off[2] + zc]
    u = c(win((0, 0, 0)))        # unit main diagonal (Jacobi preconditioned)
    for cf_ref, off in zip(cf_refs, offsets):
        u += c(cf_ref[...]) * c(win(off))
    u_ref[...] = u[None].astype(u_ref.dtype)


def _valid_tile(block: tuple[int, int] | None, zc: int,
                shape: tuple[int, int, int]) -> tuple[int, int, int]:
    """Trace-time tile validation: clamp to the nearest valid divisors.

    The kernel used to assert even division and let odd shapes (600 x 595)
    die inside Pallas; now any non-dividing tile degrades to the largest
    divisors <= the request, with a warning naming both tiles.
    """
    from repro.core.tuning import KernelConfig, validate_config

    bx, by, Z = shape
    bxc, byc = block if block is not None else (bx, by)
    cfg = validate_config(KernelConfig(block=(bxc, byc), zc=zc), shape,
                          context=" (stencil_nd_pallas)")
    return cfg.block + (cfg.zc,)


def stencil_nd_pallas(v_padded: jax.Array, coeffs: list[jax.Array],
                      offsets: tuple[tuple[int, int, int], ...], *,
                      radius: int, zc: int,
                      block: tuple[int, int] | None = None,
                      resident: bool | None = None,
                      accum_dtype=jnp.float32,
                      interpret: bool = True):
    """u = A v on one local block.

    ``v_padded``: (bx+2r, by+2r, Z+2r) iterate with halo (zero-padded for a
    standalone block, fabric-filled by ``core.halo.gather_halo`` inside the
    distributed solver), or ``(B, bx+2r, by+2r, Z+2r)`` for a batch of B
    right-hand sides — the batch folds into the grid's leading dimension
    and every coefficient tile is fetched once per spatial tile regardless
    of B (the coefficient BlockSpec ignores the batch index).
    ``coeffs[i]`` is the (bx, by, Z) diagonal that multiplies the
    ``offsets[i]``-shifted window.

    ``block``/``zc`` tile the grid (default: full-block x/y, the paper's
    layout); ``resident`` picks the VMEM form — True keeps the padded
    iterate fully resident (the only form without ``pl.Element``), False
    streams element-indexed halo'd windows per grid step.  The batched
    form is always resident (one RHS's padded block per grid step).
    """
    global _TRACED_CALLS
    r = radius
    nb = v_padded.ndim - 3       # leading batch axis (0 or 1)
    bx, by, Z = (s - 2 * r for s in v_padded.shape[nb:])
    bxc, byc, zc = _valid_tile(block, zc, (bx, by, Z))
    if resident is None:
        resident = not HAS_PL_ELEMENT
    elif not resident and not HAS_PL_ELEMENT:
        resident = True          # streaming windows need pl.Element

    if nb:
        B = v_padded.shape[0]
        grid = (B, bx // bxc, by // byc, Z // zc)
        vspec = pl.BlockSpec((1,) + v_padded.shape[1:],
                             lambda b, i, j, k: (b, 0, 0, 0))
        cspec = pl.BlockSpec((bxc, byc, zc), lambda b, i, j, k: (i, j, k))
        ospec = pl.BlockSpec((1, bxc, byc, zc), lambda b, i, j, k: (b, i, j, k))
        _TRACED_CALLS += 1
        obs_metrics.counter("kernels.stencil_nd.traced_calls").inc()
        return pl.pallas_call(
            functools.partial(
                _kernel_batched, offsets=tuple(offsets), radius=r,
                tile=(bxc, byc, zc), accum_dtype=accum_dtype),
            grid=grid,
            in_specs=[vspec] + [cspec] * len(coeffs),
            out_specs=ospec,
            out_shape=jax.ShapeDtypeStruct((B, bx, by, Z), v_padded.dtype),
            interpret=interpret,
        )(v_padded, *coeffs)

    grid = (bx // bxc, by // byc, Z // zc)
    if not resident:
        vspec = pl.BlockSpec(
            (pl.Element(bxc + 2 * r), pl.Element(byc + 2 * r),
             pl.Element(zc + 2 * r)),
            lambda i, j, k: (i * bxc, j * byc, k * zc),
        )
    else:
        vspec = pl.BlockSpec(v_padded.shape, lambda i, j, k: (0, 0, 0))
    cspec = pl.BlockSpec((bxc, byc, zc), lambda i, j, k: (i, j, k))
    _TRACED_CALLS += 1
    obs_metrics.counter("kernels.stencil_nd.traced_calls").inc()
    return pl.pallas_call(
        functools.partial(
            _kernel, offsets=tuple(offsets), radius=r,
            tile=(bxc, byc, zc), accum_dtype=accum_dtype,
            resident=resident),
        grid=grid,
        in_specs=[vspec] + [cspec] * len(coeffs),
        out_specs=cspec,
        out_shape=jax.ShapeDtypeStruct((bx, by, Z), v_padded.dtype),
        interpret=interpret,
    )(v_padded, *coeffs)
