"""jit'd wrappers for the generalized stencil kernel: tuning-cache lookup,
VMEM budgeting, padding, and the drop-in local-apply (``apply_impl=`` of
solve_distributed) that pairs the kernel with the depth-r halo exchange.

Every wrapper resolves its tile shapes through the persistent tuning cache
(``core/tuning``): a swept cell transparently gets its winning
``KernelConfig`` (x/y tile, Z split, VMEM residency, ring fusion); an
unswept cell falls back to the deterministic pre-tuning default, so an
empty cache reproduces the fixed-shape behaviour bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import resolve_interpret
from repro.core.stencil import StencilCoeffs, StencilSpec

VMEM_BUDGET_BYTES = 64 * 2 ** 20     # half of a v5e core's ~128MB VMEM


def pick_zc(bx: int, by: int, Z: int, itemsize: int, *,
            radius: int = 1, n_coeffs: int = 6,
            budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest Z chunk whose working set fits the VMEM budget."""
    r = radius
    zc = Z
    while zc > 1:
        vmem = ((bx + 2 * r) * (by + 2 * r) * (zc + 2 * r)
                + (n_coeffs + 1) * bx * by * zc) * itemsize
        if vmem <= budget and Z % zc == 0:
            return zc
        zc //= 2
    return 1


def _spec_order(coeffs: StencilCoeffs, spec: StencilSpec):
    """Diagonals in the spec's canonical order (kernel argument contract)."""
    return [coeffs.diags[n] for n in spec.names]


def tile_apply(vp: jax.Array, cf_list: list[jax.Array], spec: StencilSpec,
               config, *, accum_dtype=jnp.float32,
               interpret: bool | None = None) -> jax.Array:
    """One fused kernel pass over an r-padded block under a KernelConfig.

    The composition point between the tuning cache and the kernel: every
    apply path (standalone, blocking, overlap interior, ring patch, fused
    epilogue) funnels through here so a tuned tile applies uniformly.
    Per-element accumulation order is tile-independent (each output element
    is a canonical-order sum over offsets), so any two valid configs give
    bitwise-identical results.
    """
    from repro.kernels.stencil_nd.kernel import stencil_nd_pallas

    return stencil_nd_pallas(
        vp, cf_list, spec.offsets, radius=spec.radius, zc=config.zc,
        block=config.block, resident=config.resident,
        accum_dtype=accum_dtype, interpret=resolve_interpret(interpret))


def ring_patch_apply(exchange, cf_list: list[jax.Array], spec: StencilSpec,
                     config, u: jax.Array, fabric, *,
                     accum_dtype=jnp.float32,
                     interpret: bool | None = None) -> jax.Array:
    """The split overlap epilogue: re-run the kernel on the exchanged
    depth-r ring slabs and overwrite the ring of ``u`` — one extra kernel
    launch per boundary region (the fused epilogue folds these away).

    The patch re-runs the same Pallas kernel (not a jnp re-derivation,
    whose fusion can differ by an ulp), so overlap stays bit-identical to
    blocking.  Slab tiles are sized per-slab (a tuned full-block tile does
    not fit a depth-r slab); the slab kernels reuse the default VMEM
    chunking for their own shapes.  A batched exchange patches every RHS's
    ring in the same per-region launches (the slab kernel grids over the
    batch axis).
    """
    from repro.core import comm, tuning

    r = spec.radius
    pre = (slice(None),) * exchange.n_batch
    itemsize = jnp.dtype(exchange.padded.dtype).itemsize
    for reg in comm.boundary_regions(exchange.shape, fabric, r):
        lo_hi = [(sl.start or 0,
                  exchange.shape[i] if sl.stop is None else sl.stop)
                 for i, sl in enumerate(reg)]
        sub_shape = tuple(hi - lo for lo, hi in lo_hi)
        sub_vp = exchange.padded[pre + tuple(slice(lo, hi + 2 * r)
                                             for lo, hi in lo_hi)]
        sub_cfg = tuning.KernelConfig(
            block=sub_shape[:2],
            zc=pick_zc(*sub_shape, itemsize, radius=r,
                       n_coeffs=spec.n_offsets),
            resident=config.resident)
        patch = tile_apply(sub_vp, [c[reg] for c in cf_list], spec, sub_cfg,
                           accum_dtype=accum_dtype, interpret=interpret)
        u = u.at[pre + reg].set(patch)
    return u


@functools.partial(jax.jit, static_argnames=("spec", "accum_dtype", "interpret"))
def stencil_apply(coeffs: StencilCoeffs, v: jax.Array, *,
                  spec: StencilSpec | None = None,
                  accum_dtype=jnp.float32,
                  interpret: bool | None = None) -> jax.Array:
    """u = A v on a local block (zero-Dirichlet at block edges), any spec.

    ``v`` may carry a leading batch axis (``(B, bx, by, Z)``) — the batch
    folds into the kernel grid and the tuning lookup keys on the mesh
    shape alone (a tuned cell's config applies to every batch size).

    Tile shapes come from the tuning cache (trace-time lookup keyed by
    {spec x dtype x shape}); without an entry the deterministic default
    (full-block tile, VMEM-budgeted Z chunk) reproduces the untuned kernel.
    """
    from repro.core import tuning

    assert v.ndim in (3, 4), "the fused kernel is 3D (+ optional batch axis)"
    if coeffs.diag is not None:
        raise NotImplementedError(
            "the fused stencil kernel assumes the family's unit diagonal; "
            "raw operators go through core.operator.pallas_operator, which "
            "adds the diagonal deviation outside the kernel")
    spec = spec or coeffs.spec
    nb = v.ndim - 3
    config, _ = tuning.lookup_config(spec, v.dtype, v.shape)
    vp = jnp.pad(v, [(0, 0)] * nb + [(spec.radius, spec.radius)] * 3)
    return tile_apply(vp, _spec_order(coeffs, spec), spec, config,
                      accum_dtype=accum_dtype, interpret=interpret)


def pallas_local_apply(coeffs, v, fabric, *, policy, overlap: bool | None = None,
                       schedule=None, interpret: bool | None = None,
                       fuse_ring: bool | None = None):
    """Drop-in for halo.local_apply: depth-r halo exchange + fused kernel,
    under either communication schedule (``core.comm.SCHEDULES``).

    ``blocking``: ``gather_halo`` assembles the (bx+2r, by+2r, Z+2r) block
    (slab ``ppermute`` per split axis, corner-carrying sequential exchange
    for box specs), which is exactly the kernel's input layout — the kernel
    computes the whole product in one fused pass.

    ``overlap`` (default): the exchange is issued first, the kernel runs on
    the *zero-padded* block — the interior apply, which depends on no
    collective — and only the depth-r boundary ring is patched from the
    exchanged block.  The patch epilogue has two forms, chosen per cell by
    the tuning cache (``fuse_ring`` overrides):

    * split (default): re-run the kernel on the exchanged ring slabs —
      one extra launch per boundary region, minimal collective-dependent
      compute;
    * fused: fold the ring into the interior kernel's pass by running the
      one fused kernel over the exchanged block — a single launch per
      SpMV (2+ -> 1), at the price of the whole pass depending on the
      exchange (see ``kernels/stencil_nd/fused.py``).

    Both epilogues and the blocking path are bitwise identical: every form
    accumulates the same canonical-order terms per element.
    """
    from repro.core import comm, tuning
    from repro.kernels.stencil_nd.fused import fused_ring_apply

    if coeffs.diag is not None:
        raise NotImplementedError(
            "the fused stencil kernel assumes the family's unit diagonal; "
            "raw operators go through core.operator.pallas_operator, which "
            "adds the diagonal deviation outside the kernel")
    spec = coeffs.spec
    r = spec.radius
    cf = coeffs.astype(policy.storage)
    vs = v.astype(policy.storage)
    nb = vs.ndim - cf.ndim       # leading batch (many-RHS) axes
    cf_list = _spec_order(cf, spec)
    config, _ = tuning.lookup_config(spec, vs.dtype, vs.shape)
    fuse = config.fuse_ring if fuse_ring is None else bool(fuse_ring)

    def kernel(vp):
        return tile_apply(vp, cf_list, spec, config,
                          accum_dtype=policy.compute, interpret=interpret)

    def patch_ring(exchange, u):
        return ring_patch_apply(exchange, cf_list, spec, config, u, fabric,
                                accum_dtype=policy.compute,
                                interpret=interpret)

    fused_fn = None
    if fuse:
        def fused_fn(exchange):
            return fused_ring_apply(exchange, cf_list, spec, config,
                                    accum_dtype=policy.compute,
                                    interpret=interpret)

    return comm.scheduled_apply(
        cf, vs, fabric, policy=policy,
        schedule=schedule if schedule is not None else overlap,
        full_fn=kernel,
        interior_fn=lambda vv: kernel(
            jnp.pad(vv, [(0, 0)] * nb + [(r, r)] * cf.ndim)),
        patch_fn=patch_ring,
        fused_fn=fused_fn)
