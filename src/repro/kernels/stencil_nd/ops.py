"""jit'd wrappers for the generalized stencil kernel: VMEM budgeting,
padding, and the drop-in local-apply (``apply_impl=`` of solve_distributed)
that pairs the kernel with the depth-r halo exchange."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import resolve_interpret
from repro.core.stencil import StencilCoeffs, StencilSpec

VMEM_BUDGET_BYTES = 64 * 2 ** 20     # half of a v5e core's ~128MB VMEM


def pick_zc(bx: int, by: int, Z: int, itemsize: int, *,
            radius: int = 1, n_coeffs: int = 6,
            budget: int = VMEM_BUDGET_BYTES) -> int:
    """Largest Z chunk whose working set fits the VMEM budget."""
    r = radius
    zc = Z
    while zc > 1:
        vmem = ((bx + 2 * r) * (by + 2 * r) * (zc + 2 * r)
                + (n_coeffs + 1) * bx * by * zc) * itemsize
        if vmem <= budget and Z % zc == 0:
            return zc
        zc //= 2
    return 1


def _spec_order(coeffs: StencilCoeffs, spec: StencilSpec):
    """Diagonals in the spec's canonical order (kernel argument contract)."""
    return [coeffs.diags[n] for n in spec.names]


@functools.partial(jax.jit, static_argnames=("spec", "accum_dtype", "interpret"))
def stencil_apply(coeffs: StencilCoeffs, v: jax.Array, *,
                  spec: StencilSpec | None = None,
                  accum_dtype=jnp.float32,
                  interpret: bool | None = None) -> jax.Array:
    """u = A v on a local block (zero-Dirichlet at block edges), any spec."""
    from repro.kernels.stencil_nd.kernel import stencil_nd_pallas

    assert v.ndim == 3, "the fused kernel is 3D"
    if coeffs.diag is not None:
        raise NotImplementedError(
            "the fused stencil kernel assumes the family's unit diagonal; "
            "raw operators go through core.operator.pallas_operator, which "
            "adds the diagonal deviation outside the kernel")
    interpret = resolve_interpret(interpret)
    spec = spec or coeffs.spec
    r = spec.radius
    bx, by, Z = v.shape
    zc = pick_zc(bx, by, Z, jnp.dtype(v.dtype).itemsize,
                 radius=r, n_coeffs=spec.n_offsets)
    vp = jnp.pad(v, r)
    return stencil_nd_pallas(vp, _spec_order(coeffs, spec), spec.offsets,
                             radius=r, zc=zc, accum_dtype=accum_dtype,
                             interpret=interpret)


def pallas_local_apply(coeffs, v, fabric, *, policy, overlap: bool | None = None,
                       schedule=None, interpret: bool | None = None):
    """Drop-in for halo.local_apply: depth-r halo exchange + fused kernel,
    under either communication schedule (``core.comm.SCHEDULES``).

    ``blocking``: ``gather_halo`` assembles the (bx+2r, by+2r, Z+2r) block
    (slab ``ppermute`` per split axis, corner-carrying sequential exchange
    for box specs), which is exactly the kernel's input layout — the kernel
    computes the whole product in one fused pass.

    ``overlap`` (default): the exchange is issued first, the kernel runs on
    the *zero-padded* block — the interior apply, which depends on no
    collective — and only the depth-r boundary ring is patched from the
    exchanged block.  The patch re-runs the same Pallas kernel on the ring
    slabs (not a jnp re-derivation, whose fusion can differ by an ulp), so
    the result is bit-identical to blocking.
    """
    from repro.core import comm
    from repro.kernels.stencil_nd.kernel import stencil_nd_pallas

    if coeffs.diag is not None:
        raise NotImplementedError(
            "the fused stencil kernel assumes the family's unit diagonal; "
            "raw operators go through core.operator.pallas_operator, which "
            "adds the diagonal deviation outside the kernel")
    interpret = resolve_interpret(interpret)
    spec = coeffs.spec
    r = spec.radius
    cf = coeffs.astype(policy.storage)
    vs = v.astype(policy.storage)
    itemsize = jnp.dtype(vs.dtype).itemsize
    cf_list = _spec_order(cf, spec)

    def kernel(vp):
        bx, by, Z = (s - 2 * r for s in vp.shape)
        zc = pick_zc(bx, by, Z, itemsize, radius=r, n_coeffs=spec.n_offsets)
        return stencil_nd_pallas(vp, cf_list, spec.offsets, radius=r, zc=zc,
                                 accum_dtype=policy.compute,
                                 interpret=interpret)

    def patch_ring(exchange, u):
        # re-run the same kernel on the exchanged ring slabs (not a jnp
        # re-derivation, whose fusion can differ by an ulp from the kernel)
        for reg in comm.boundary_regions(v.shape, fabric, r):
            lo_hi = [(sl.start or 0, v.shape[i] if sl.stop is None else sl.stop)
                     for i, sl in enumerate(reg)]
            sub_vp = exchange.padded[tuple(slice(lo, hi + 2 * r)
                                           for lo, hi in lo_hi)]
            patch = stencil_nd_pallas(
                sub_vp, [c[reg] for c in cf_list], spec.offsets, radius=r,
                zc=pick_zc(*(hi - lo for lo, hi in lo_hi), itemsize,
                           radius=r, n_coeffs=spec.n_offsets),
                accum_dtype=policy.compute, interpret=interpret)
            u = u.at[reg].set(patch)
        return u

    return comm.scheduled_apply(
        cf, vs, fabric, policy=policy,
        schedule=schedule if schedule is not None else overlap,
        full_fn=kernel,
        interior_fn=lambda vv: kernel(jnp.pad(vv, r)),
        patch_fn=patch_ring)
