"""Pure-jnp oracle for the generalized stencil kernel: identical to
core.stencil.apply_ref restricted to a local (zero-Dirichlet) block, but
taking the kernel's own argument layout (ordered coeff list + offsets) so
kernel tests exercise the argument contract too."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _shift_nd(v, off):
    for axis, o in enumerate(off):
        if o == 0:
            continue
        pad = [(0, 0)] * v.ndim
        sl = [slice(None)] * v.ndim
        if o > 0:
            pad[axis] = (0, o)
            sl[axis] = slice(o, None)
        else:
            pad[axis] = (-o, 0)
            sl[axis] = slice(0, o)
        v = jnp.pad(v, pad)[tuple(sl)]
    return v


def stencil_nd_ref(v: jax.Array, coeffs: list[jax.Array],
                   offsets, accum_dtype=jnp.float32) -> jax.Array:
    """coeffs[i] multiplies the offsets[i]-shifted iterate (kernel order)."""
    vc = v.astype(accum_dtype)
    u = vc
    for cf, off in zip(coeffs, offsets):
        u = u + cf.astype(accum_dtype) * _shift_nd(vc, off)
    return u.astype(v.dtype)
