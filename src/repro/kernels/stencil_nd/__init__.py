from repro.kernels.stencil_nd.ops import (  # noqa: F401
    pallas_local_apply,
    pick_zc,
    ring_patch_apply,
    stencil_apply,
    tile_apply,
)
from repro.kernels.stencil_nd.ref import stencil_nd_ref  # noqa: F401
