"""Checkpointing: atomic, async, elastic (reshard-on-load).

Wire format: one ``.npz`` per checkpoint step holding every pytree leaf as a
full (unsharded) array, plus a JSON manifest with the tree structure, the
step, and an integrity digest.  Writes go to a temp name and are renamed
into place (atomic on POSIX), and the manifest is written last, so a crash
mid-write can never yield a checkpoint that loads — the runner simply falls
back to the previous manifest (tested in tests/test_runtime.py).

Storing logical (unsharded) arrays is what makes restarts *elastic*: a
checkpoint written on an N-device mesh restores onto any mesh whose sharding
divides the shapes — jax.device_put with the new NamedSharding reshards.
At 1000+ node scale this trades write bandwidth for operational simplicity;
the manifest format is deliberately shard-layout-free so a sharded-file
backend can be swapped in without invalidating old checkpoints.

Async: ``save(..., blocking=False)`` snapshots to host memory synchronously
(cheap) and writes in a background thread, keeping serialization off the
training critical path (straggler lever (b) in DESIGN.md §9).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading

import jax
import ml_dtypes
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

# npz can't hold non-native dtypes; store them as same-width uint views and
# record the logical dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None

    # -- paths ---------------------------------------------------------------

    def _npz(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}.npz")

    def _manifest(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}.json")

    def all_steps(self) -> list[int]:
        steps = []
        for f in os.listdir(self.dir):
            if f.endswith(".json") and f.startswith("step_"):
                steps.append(int(f[5:-5]))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        # snapshot to host synchronously; device buffers may be donated next step
        host = [np.asarray(l) for l in leaves]
        if self._pending is not None:
            self._pending.join()

        def write():
            # runs on the writer thread for async saves; spans/counters are
            # thread-safe (the registry locks, span stacks are thread-local)
            with self._lock, obs_trace.span("checkpoint.write", step=step,
                                            blocking=blocking):
                tmp = self._npz(step) + ".tmp.npz"  # savez appends .npz itself
                stored = [
                    a.view(_VIEW_AS[str(a.dtype)]) if str(a.dtype) in _VIEW_AS else a
                    for a in host
                ]
                np.savez(tmp, **{f"leaf_{i}": a for i, a in enumerate(stored)})
                os.replace(tmp, self._npz(step))
                digest = hashlib.sha256()
                for a in host:
                    digest.update(np.ascontiguousarray(a).tobytes()[:4096])
                man = {
                    "step": step,
                    "n_leaves": len(host),
                    "treedef": str(treedef),
                    "digest": digest.hexdigest(),
                    "shapes": [list(a.shape) for a in host],
                    "dtypes": [str(a.dtype) for a in host],
                }
                mtmp = self._manifest(step) + ".tmp"
                with open(mtmp, "w") as f:
                    json.dump(man, f)
                os.replace(mtmp, self._manifest(step))
                self._gc()

        obs_metrics.counter("checkpoint.saves").inc()
        obs_metrics.counter(
            "checkpoint.saves_async" if not blocking else
            "checkpoint.saves_blocking").inc()
        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            for p in (self._npz(s), self._manifest(s)):
                if os.path.exists(p):
                    os.remove(p)

    # -- restore ---------------------------------------------------------------

    def restore(self, step: int, like):
        """Restore into the structure/shardings of ``like`` (reshard-on-load).

        ``like`` may hold arrays or ShapeDtypeStructs; leaves that carry a
        sharding are placed with it (elastic restart onto a different mesh).
        """
        obs_metrics.counter("checkpoint.restores").inc()
        with open(self._manifest(step)) as f:
            man = json.load(f)
        with obs_trace.span("checkpoint.restore", step=step):
            data = np.load(self._npz(step))
        leaves_like, treedef = jax.tree.flatten(like)
        assert man["n_leaves"] == len(leaves_like), "tree structure changed"
        out = []
        for i, leaf in enumerate(leaves_like):
            arr = data[f"leaf_{i}"]
            logical = man["dtypes"][i]
            if logical in _VIEW_AS:
                arr = arr.view(np.dtype(getattr(ml_dtypes, logical)))
            sharding = getattr(leaf, "sharding", None)
            dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(dtype)
            if sharding is not None:
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jax.device_put(arr))
        return treedef.unflatten(out), man["step"]

    def restore_latest(self, like):
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, like)
