from repro.runtime.runner import FaultTolerantRunner, RunnerConfig  # noqa: F401
