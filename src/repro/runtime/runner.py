"""Fault-tolerant training runner: checkpoint/restart, failure injection,
elastic resume.

The control loop a 1000+ node deployment needs, reduced to its testable
core:

* periodic async checkpoints (optimizer state + params + step);
* on ANY step failure (preemption, hardware fault — injected in tests via
  ``failure_hook``), restore the latest valid checkpoint and replay: because
  the data pipeline is stateless in (seed, step), replay is bit-deterministic;
* bounded retry budget, then surface the failure;
* restart works onto a different device topology (CheckpointManager reshards).

On a real pod this loop runs per-controller with jax.distributed; the logic
is identical — which is the point of keeping it free of device specifics.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable


from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

log = logging.getLogger(__name__)


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    checkpoint_every: int = 50
    max_restarts: int = 3
    async_checkpoint: bool = True


class FaultTolerantRunner:
    def __init__(self, cfg: RunnerConfig, *, train_step: Callable,
                 data: SyntheticLMData, ckpt: CheckpointManager,
                 failure_hook: Callable[[int], None] | None = None):
        self.cfg = cfg
        self.train_step = train_step
        self.data = data
        self.ckpt = ckpt
        self.failure_hook = failure_hook
        self.restarts = 0
        self.metrics_history: list[dict] = []

    def _restore_or_init(self, params, opt_state):
        latest = self.ckpt.restore_latest((params, opt_state))
        if latest is None:
            return params, opt_state, 0
        (params, opt_state), step = latest
        log.info("restored checkpoint at step %d", step)
        return params, opt_state, step

    def run(self, params, opt_state):
        """Run to total_steps, surviving injected failures. Returns final state."""
        state = self._restore_or_init(params, opt_state)
        while True:
            try:
                return self._run_from(*state)
            except Exception as e:  # noqa: BLE001 — the whole point
                self.restarts += 1
                obs_metrics.counter("runner.restarts").inc()
                obs_metrics.event("runner_restart", restart=self.restarts,
                                  error=f"{type(e).__name__}: {e}")
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded restart budget ({self.cfg.max_restarts})") from e
                log.warning("step failed (%s); restart %d", e, self.restarts)
                self.ckpt.wait()
                restored = self.ckpt.restore_latest((state[0], state[1]))
                if restored is None:
                    state = (state[0], state[1], 0)
                else:
                    (p, o), step = restored
                    state = (p, o, step)

    def _run_from(self, params, opt_state, start_step: int):
        step = start_step
        for step, batch in self.data.iterate(start_step):
            if step >= self.cfg.total_steps:
                break
            if self.failure_hook is not None:
                self.failure_hook(step)      # may raise: injected fault
            with obs_trace.span("runner.step", step=step):
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
            obs_metrics.counter("runner.steps").inc()
            self.metrics_history.append(
                {"step": step, **{k: float(v) for k, v in metrics.items()}})
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, (params, opt_state),
                               blocking=not self.cfg.async_checkpoint)
        self.ckpt.wait()
        self.ckpt.save(self.cfg.total_steps, (params, opt_state), blocking=True)
        return params, opt_state
