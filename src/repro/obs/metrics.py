"""Process-local metrics registry: counters, gauges, histograms, events.

One global :class:`Registry` collects everything a run emits — solver
iterations and per-RHS convergence (from ``SolveResult``), residual
histories, AllReduce/ppermute counts (the HLO-counting idiom the tests
use, lifted here as :func:`count_collectives`), ``kernels/stencil_nd``
launch counts, tuning-cache hit/miss/stale, and the achieved-vs-peak
roofline fraction the paper reports (~1/3 of peak on the CS-1).

The registry is always on (counter bumps are a dict lookup + integer
add); *spans* are the opt-in part of observability.  Tests get a clean
slate from the autouse reset fixture in ``tests/conftest.py``.

Instrumented code must only feed **concrete** values: inside jit the
fields of a ``SolveResult`` are tracers, so :func:`record_solve` guards
with :func:`is_concrete` and silently no-ops under tracing — emission
happens at the driver level where results are real arrays.
"""

from __future__ import annotations

import threading
import time


def is_concrete(x) -> bool:
    """True when ``x`` can be read as a host value (not a jax tracer)."""
    import numpy as np

    try:
        np.asarray(x)
        return True
    except Exception:
        return False


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming summary + a bounded reservoir of raw observations."""

    MAX_SAMPLES = 1024
    __slots__ = ("count", "total", "min", "max", "last", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.last = None
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.last = v
        if len(self.samples) < self.MAX_SAMPLES:
            self.samples.append(v)

    def summary(self) -> dict:
        mean = self.total / self.count if self.count else None
        return {"count": self.count, "total": self.total, "mean": mean,
                "min": self.min, "max": self.max, "last": self.last}


class Registry:
    """Process-local named metrics plus an append-only event log."""

    MAX_EVENTS = 100_000

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, Histogram] = {}
        self.events: list[dict] = []

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self.counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            return self.gauges.setdefault(name, Gauge())

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            return self.histograms.setdefault(name, Histogram())

    def event(self, kind: str, /, **fields) -> dict:
        ev = {"ts": time.time(), "event": kind, **fields}
        with self._lock:
            if len(self.events) < self.MAX_EVENTS:
                self.events.append(ev)
        return ev

    def snapshot(self) -> dict:
        """JSON-ready view of every metric (events excluded — they go to
        ``events.jsonl`` via the run manifest)."""
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self.counters.items()},
                "gauges": {k: g.value for k, g in self.gauges.items()},
                "histograms": {k: h.summary()
                               for k, h in self.histograms.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()
            self.events.clear()


REGISTRY = Registry()

# Module-level conveniences bound to the global registry.
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
event = REGISTRY.event
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset


def events() -> list[dict]:
    with REGISTRY._lock:
        return list(REGISTRY.events)


# ---------------------------------------------------------------------------
# HLO collective counting — the exact idiom the tests/benchmarks assert with
# (both mnemonic spellings appear across StableHLO/HLO dumps).

def count_collectives(hlo_text: str) -> dict:
    """AllReduce / ppermute totals in lowered HLO (or StableHLO) text."""
    return {
        "allreduce_total": (hlo_text.count("all_reduce")
                            + hlo_text.count("all-reduce")),
        "ppermute_total": (hlo_text.count("collective_permute")
                           + hlo_text.count("collective-permute")),
    }


def record_collectives(hlo_text: str, **labels) -> dict:
    """Count collectives in ``hlo_text``, mirror into gauges, and append a
    ``collectives`` event carrying the labels (solver, schedule, nrhs...)."""
    counts = count_collectives(hlo_text)
    prefix = labels.get("solver", "solve")
    gauge(f"collectives.{prefix}.allreduce_total").set(
        counts["allreduce_total"])
    gauge(f"collectives.{prefix}.ppermute_total").set(
        counts["ppermute_total"])
    event("collectives", **labels, **counts)
    return counts


# ---------------------------------------------------------------------------
# Roofline accounting (the paper's achieved-vs-peak framing).

def roofline_fraction(achieved_flops_per_s: float,
                      peak_flops_per_s: float | None = None) -> float:
    """Achieved / peak FLOP fraction; peak defaults to the perfmodel's
    wafer-scale peak so launch paths report the paper's metric unmodified."""
    if peak_flops_per_s is None:
        from repro.core import perfmodel

        peak_flops_per_s = perfmodel.PEAK_FLOPS
    frac = achieved_flops_per_s / peak_flops_per_s
    gauge("roofline.achieved_flops_per_s").set(achieved_flops_per_s)
    gauge("roofline.fraction").set(frac)
    return frac


# ---------------------------------------------------------------------------
# Per-solve emission from a SolveResult (solver-agnostic: the pipelined
# solvers share the generic history semantics — see core/solvers/pipelined).

def record_solve(result, *, wall_s: float | None = None, **labels) -> dict | None:
    """Emit iterations / convergence / residual metrics for one solve.

    ``result`` is any ``SolveResult``-shaped object.  No-ops (returns
    ``None``) when the fields are tracers, i.e. when called under jit —
    emission belongs at the driver level where values are concrete.
    """
    import numpy as np

    if not is_concrete(result.iterations):
        return None
    iters = np.asarray(result.iterations)
    conv = np.asarray(result.converged)
    rel = np.asarray(result.rel_residual)
    brk = np.asarray(result.breakdown)
    n_rhs = int(iters.size)

    counter("solve.total").inc()
    counter("solve.rhs_total").inc(n_rhs)
    counter("solve.rhs_converged").inc(int(conv.sum()))
    counter("solve.breakdowns").inc(int(brk.sum()))
    for it in iters.reshape(-1):
        histogram("solve.iterations").observe(float(it))
    gauge("solve.iterations_max").set(float(iters.max()))
    gauge("solve.rel_residual_max").set(float(rel.max()))
    if wall_s is not None:
        histogram("solve.wall_s").observe(wall_s)
        gauge("solve.solves_per_sec").set(n_rhs / wall_s if wall_s else 0.0)

    ev = {
        "iterations": np.asarray(iters).reshape(-1).astype(int).tolist(),
        "converged": conv.reshape(-1).astype(bool).tolist(),
        "rel_residual": rel.reshape(-1).astype(float).tolist(),
        "breakdown": brk.reshape(-1).astype(bool).tolist(),
        "n_rhs": n_rhs,
    }
    if wall_s is not None:
        ev["wall_s"] = wall_s
    hist = getattr(result, "history", None)
    if hist is not None and is_concrete(hist):
        h = np.asarray(hist, dtype=float)
        # history[k] = relative residual after iteration k+1 (all solvers)
        ev["history"] = h[: int(iters.max())].tolist()
    return event("solve", **labels, **ev)
