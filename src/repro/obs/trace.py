"""Nestable spans with Chrome-trace export; zero-cost no-ops when disabled.

Spans are plain Python context managers and therefore live *outside* jit:
inside traced code they time *tracing*, not device execution, and insert
no jaxprs — which is exactly why enabling observability cannot change
lowered HLO (tests assert bit-identical HLO text with obs on/off).

To time device work, opt into sync timing (``enable(sync=True)`` or a
per-span ``sync=True``) and hand the span the values to wait on::

    with trace.span("solve", solver="bicgstab") as sp:
        res = solve(...)
        sp.block(res.x)        # block_until_ready iff sync timing is on

``chrome_trace()`` returns the completed spans as Chrome trace events
(``ph: "X"``, microsecond timestamps) — load the exported ``trace.json``
at https://ui.perfetto.dev.  ``profile(dir)`` wraps a region in
``jax.profiler.trace`` for the ``--profile`` launch flag.
"""

from __future__ import annotations

import contextlib
import os
import threading
import time

_ENABLED = False
_SYNC = False
_EVENTS: list[dict] = []
_LOCK = threading.Lock()
_TLS = threading.local()
# Process epoch: Chrome trace timestamps are relative microseconds.
_EPOCH = time.perf_counter()


def enable(*, sync: bool = False) -> None:
    """Turn span recording on; ``sync=True`` makes ``Span.block`` wait on
    device values so span durations include device execution."""
    global _ENABLED, _SYNC
    _ENABLED = True
    _SYNC = bool(sync)


def disable() -> None:
    global _ENABLED, _SYNC
    _ENABLED = False
    _SYNC = False


def is_enabled() -> bool:
    return _ENABLED


def reset() -> None:
    """Drop all recorded spans (and any dangling thread-local stacks)."""
    with _LOCK:
        _EVENTS.clear()
    _TLS.stack = []


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class Span:
    """A single recorded span.  Use via :func:`span`, not directly."""

    __slots__ = ("name", "attrs", "t0", "depth", "parent")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.depth = 0
        self.parent = None

    def __enter__(self) -> "Span":
        st = _stack()
        self.parent = st[-1].name if st else None
        self.depth = len(st)
        st.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        ev = {
            "name": self.name,
            "ts_us": (self.t0 - _EPOCH) * 1e6,
            "dur_us": (t1 - self.t0) * 1e6,
            "depth": self.depth,
            "parent": self.parent,
            "thread": threading.get_ident(),
        }
        if self.attrs:
            ev["attrs"] = self.attrs
        with _LOCK:
            _EVENTS.append(ev)

    def block(self, value):
        """``jax.block_until_ready(value)`` iff sync timing is on; always
        returns ``value`` so call sites can write ``x = sp.block(x)``."""
        if _SYNC or self.attrs.get("sync"):
            import jax

            value = jax.block_until_ready(value)
        return value

    def set(self, **attrs) -> None:
        """Attach extra attributes to the span after entry."""
        self.attrs.update(attrs)


class _NullSpan:
    """Singleton stand-in when tracing is disabled: every method is a no-op
    so instrumented code pays one predicate check and nothing else."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def block(self, value):
        return value

    def set(self, **attrs) -> None:
        pass


_NULL = _NullSpan()


def span(name: str, **attrs):
    """Open a (nestable) span.  Returns the no-op singleton when disabled."""
    if not _ENABLED:
        return _NULL
    return Span(name, attrs)


def events() -> list[dict]:
    """Completed spans, oldest first (a copy)."""
    with _LOCK:
        return list(_EVENTS)


def chrome_trace() -> dict:
    """Completed spans as a Chrome trace-event document (Perfetto-loadable)."""
    pid = os.getpid()
    out = []
    with _LOCK:
        for ev in _EVENTS:
            out.append({
                "name": ev["name"],
                "ph": "X",
                "ts": ev["ts_us"],
                "dur": ev["dur_us"],
                "pid": pid,
                "tid": ev["thread"],
                "args": dict(ev.get("attrs", {}), depth=ev["depth"]),
            })
    out.sort(key=lambda e: e["ts"])
    return {"traceEvents": out, "displayTimeUnit": "ms"}


@contextlib.contextmanager
def profile(log_dir: str):
    """Wrap a region in ``jax.profiler.trace`` (the ``--profile`` hook).

    Degrades to a plain pass-through if the profiler is unavailable in
    this jax build rather than failing the run.
    """
    try:
        import jax

        ctx = jax.profiler.trace(log_dir)
    except Exception:  # pragma: no cover - profiler missing/broken build
        ctx = contextlib.nullcontext()
    with ctx:
        yield
