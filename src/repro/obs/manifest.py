"""Run manifests: every launch/benchmark invocation leaves a reproducible
bundle under ``results/runs/<run_id>/``:

* ``manifest.json`` — versioned ``repro.obs.v1`` record: config cell,
  git SHA, jax/jaxlib/numpy versions, device topology, XLA/env flags,
  wall time, and the final metrics snapshot.
* ``events.jsonl``  — the registry's structured events, one per line.
* ``trace.json``    — completed spans as Chrome trace events (Perfetto).

Usage (what ``--obs`` wires up in the launch CLIs)::

    ctx = manifest.start_run("solve", config=vars(args), profile=args.profile)
    ... run ...
    manifest.finish_run(ctx)

``scripts/compare_runs.py`` diffs two such bundles and CI validates
their schema, so keep :func:`validate_manifest` in sync with the writer.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import time

from repro.obs import metrics, trace

SCHEMA = "repro.obs.v1"
DEFAULT_ROOT = os.path.join("results", "runs")

# Env vars worth pinning in the manifest: anything that changes lowering,
# device fabric, kernels, or cache behavior.
_ENV_KEYS = ("XLA_FLAGS", "JAX_ENABLE_X64", "JAX_PLATFORMS",
             "REPRO_DEVICES", "REPRO_PALLAS_INTERPRET", "REPRO_TUNING_CACHE",
             "LD_PRELOAD", "TF_CPP_MIN_LOG_LEVEL")

_REQUIRED_FIELDS = ("schema", "run_id", "kind", "created_unix", "created",
                    "argv", "config", "git", "versions", "devices", "env",
                    "metrics", "wall_s")


def git_info() -> dict:
    """Best-effort git SHA/branch/dirty for the working tree."""
    def _run(*cmd):
        try:
            out = subprocess.run(["git", *cmd], capture_output=True,
                                 text=True, timeout=10)
            return out.stdout.strip() if out.returncode == 0 else None
        except Exception:
            return None

    sha = _run("rev-parse", "HEAD")
    return {
        "sha": sha or "unknown",
        "branch": _run("rev-parse", "--abbrev-ref", "HEAD") or "unknown",
        "dirty": bool(_run("status", "--porcelain")) if sha else None,
    }


def versions() -> dict:
    out = {"python": platform.python_version()}
    for mod in ("jax", "jaxlib", "numpy"):
        try:
            out[mod] = __import__(mod).__version__
        except Exception:
            out[mod] = None
    return out


def device_topology() -> dict:
    """Device platform/count as jax sees it (fake fabrics included)."""
    try:
        import jax

        devs = jax.devices()
        return {
            "platform": devs[0].platform if devs else None,
            "n_devices": len(devs),
            "kinds": sorted({d.device_kind for d in devs}),
            "process_count": jax.process_count(),
        }
    except Exception:
        return {"platform": None, "n_devices": 0, "kinds": [],
                "process_count": None}


def env_flags() -> dict:
    return {k: os.environ[k] for k in _ENV_KEYS if k in os.environ}


def new_run_id(kind: str) -> str:
    stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime())
    return f"{stamp}-{kind}-{os.getpid() % 100000:05d}"


def _jsonable(obj):
    """Coerce argparse namespaces / dataclasses / tuples into JSON."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


@dataclasses.dataclass
class RunContext:
    run_id: str
    run_dir: str
    kind: str
    config: dict
    t_start: float
    profile: bool = False
    _profiler = None


def start_run(kind: str, *, config: dict | None = None,
              run_dir: str | None = None, root: str = DEFAULT_ROOT,
              profile: bool = False) -> RunContext:
    """Open a run bundle directory (creating it) and optionally start the
    jax profiler into ``<run_dir>/jax_profile``."""
    run_id = new_run_id(kind)
    if run_dir is None:
        run_dir = os.path.join(root, run_id)
    os.makedirs(run_dir, exist_ok=True)
    ctx = RunContext(run_id=run_id, run_dir=run_dir, kind=kind,
                     config=_jsonable(config or {}),
                     t_start=time.time(), profile=profile)
    if profile:
        try:
            import jax

            ctx._profiler = jax.profiler.trace(
                os.path.join(run_dir, "jax_profile"))
            ctx._profiler.__enter__()
        except Exception:  # pragma: no cover - profiler-less builds
            ctx._profiler = None
    metrics.event("run_start", run_id=run_id, kind=kind)
    return ctx


def finish_run(ctx: RunContext, *, extra: dict | None = None) -> dict:
    """Write ``manifest.json``, ``events.jsonl``, and ``trace.json``."""
    if ctx._profiler is not None:
        try:
            ctx._profiler.__exit__(None, None, None)
        except Exception:  # pragma: no cover
            pass
        ctx._profiler = None
    wall = time.time() - ctx.t_start
    metrics.event("run_finish", run_id=ctx.run_id, wall_s=wall)

    man = {
        "schema": SCHEMA,
        "run_id": ctx.run_id,
        "kind": ctx.kind,
        "created_unix": ctx.t_start,
        "created": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                 time.gmtime(ctx.t_start)),
        "argv": list(sys.argv),
        "config": ctx.config,
        "git": git_info(),
        "versions": versions(),
        "devices": device_topology(),
        "env": env_flags(),
        "metrics": metrics.snapshot(),
        "wall_s": wall,
    }
    if extra:
        man.update(_jsonable(extra))

    with open(os.path.join(ctx.run_dir, "events.jsonl"), "w") as f:
        for ev in metrics.events():
            f.write(json.dumps(_jsonable(ev)) + "\n")
    with open(os.path.join(ctx.run_dir, "trace.json"), "w") as f:
        json.dump(trace.chrome_trace(), f)
    with open(os.path.join(ctx.run_dir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=2)
    return man


def validate_manifest(man: dict) -> list[str]:
    """Schema check used by tests, CI, and compare_runs.  Returns a list of
    problems (empty == valid)."""
    problems = []
    for field in _REQUIRED_FIELDS:
        if field not in man:
            problems.append(f"missing field: {field}")
    if man.get("schema") != SCHEMA:
        problems.append(f"schema is {man.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(man.get("metrics"), dict):
        problems.append("metrics is not an object")
    else:
        for sub in ("counters", "gauges", "histograms"):
            if sub not in man["metrics"]:
                problems.append(f"metrics missing {sub!r}")
    git = man.get("git")
    if not (isinstance(git, dict) and "sha" in git):
        problems.append("git.sha missing")
    dev = man.get("devices")
    if not (isinstance(dev, dict) and "n_devices" in dev):
        problems.append("devices.n_devices missing")
    return problems


def load_manifest(run_dir: str) -> dict:
    with open(os.path.join(run_dir, "manifest.json")) as f:
        return json.load(f)


def write_benchmark_bundle(name: str, record: dict,
                           root: str = DEFAULT_ROOT) -> str:
    """One-shot bundle for a benchmark record (the benchmarks/run.py hook):
    the record lands both as a ``benchmark_record`` event and as
    ``record.json`` next to the manifest.  Returns the run directory."""
    ctx = start_run(f"bench-{name}", config={"benchmark": name})
    metrics.event("benchmark_record", name=name,
                  schema=record.get("schema"),
                  generated_by=record.get("generated_by"))
    with open(os.path.join(ctx.run_dir, "record.json"), "w") as f:
        json.dump(_jsonable(record), f, indent=2)
    finish_run(ctx, extra={"benchmark": name})
    return ctx.run_dir
