"""Unified observability layer: spans, metrics, and run manifests.

Three small, dependency-light modules threaded through the solver stack:

* :mod:`repro.obs.trace` — nestable context-manager spans with opt-in
  ``block_until_ready`` device-sync timing and Chrome-trace-event
  (Perfetto-loadable) export.  Spans live *outside* jit: enabling them
  cannot change lowered HLO (asserted in tests/test_obs.py).
* :mod:`repro.obs.metrics` — a process-local registry of counters,
  gauges, histograms and structured events: solver iterations, per-RHS
  convergence, AllReduce/ppermute counts, kernel launch counts,
  tuning-cache hit/miss/stale, roofline fraction.
* :mod:`repro.obs.manifest` — run bundles under
  ``results/runs/<run_id>/{manifest.json,events.jsonl,trace.json}``
  with a versioned ``repro.obs.v1`` schema (config cell, git SHA,
  jax/jaxlib versions, device topology, XLA/env flags).

Nothing in this package imports from ``repro.core`` — the core modules
import *us*, so the dependency edge only points one way.
"""

from repro.obs import manifest, metrics, trace

__all__ = ["manifest", "metrics", "trace"]
