"""Version gates for the jax API surface this repo uses.

The code targets current jax (>= 0.6: ``jax.shard_map``, mesh
``axis_types``, Pallas ``pl.Element`` block indexing) but must also run on
the jax 0.4.x line.  Every version-sensitive call site goes through this
module so the rest of the codebase stays on the modern spelling.

Nothing here is installed lazily — if an API is missing we fall back to the
older equivalent, never to a stub that silently does nothing.
"""

from __future__ import annotations

import os

import jax
from jax.experimental import pallas as pl


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with fallback to the experimental module.

    ``check_vma`` (>= 0.7) and ``check_rep`` (0.4.x) gate the same
    replication/varying-manual-axes checker, so the flag maps across.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def make_mesh(shape, axis_names, **kwargs):
    """``jax.make_mesh`` with explicit Auto axis types where supported
    (silences the 0.8 -> 0.9 deprecation warning; older jax has no
    ``axis_types`` and defaults to the same behaviour).  Extra kwargs
    (e.g. ``devices=``) pass through on every version."""
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(shape, axis_names, **kwargs)


def get_abstract_mesh():
    """The ambient abstract mesh, or None when no mesh is set.

    jax 0.4.x predates the ambient-mesh context entirely, so there is never
    an abstract mesh to report — callers (e.g. ``models.param.constrain``)
    already treat None as "single device / smoke test, skip constraints".
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        return mesh if mesh is None or mesh.axis_names else None
    return None


def set_mesh(mesh):
    """``jax.sharding.set_mesh`` context, falling back to the legacy
    ``with mesh:`` physical-mesh context on jax 0.4.x (sharding constraints
    then no-op via :func:`get_abstract_mesh` returning None, which keeps
    single-process smoke paths running)."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def pallas_interpret_default() -> bool:
    """Whether Pallas calls should run in interpret mode on this backend.

    Interpret mode is required wherever there is no compiled Pallas target:
    the kernels in this repo are written for the TPU (Mosaic) lowering, so
    CPU (and GPU, where the Triton lowering would need different tiling)
    fall back to the interpreter.  ``REPRO_PALLAS_INTERPRET=0/1`` overrides
    the detection — the one switch for the whole kernel surface.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a per-call ``interpret=None`` default to the backend policy."""
    return pallas_interpret_default() if interpret is None else bool(interpret)


#: True when Pallas supports element-indexed BlockSpecs (``pl.Element``),
#: which the stencil kernels use to read overlapping halo'd z-windows.
#: Without it the kernels keep the padded iterate fully resident and slice
#: the window with ``lax.dynamic_slice`` inside the kernel body instead.
HAS_PL_ELEMENT = hasattr(pl, "Element")
