"""int8 gradient compression with error feedback (distributed-optimization
trick for the data-parallel AllReduce at 1000+ node scale).

Each leaf is quantized to int8 with a per-leaf f32 scale before the
cross-replica reduction; the quantization error is carried to the next step
(error feedback) so convergence is preserved (tested on a quadratic and on
the LM smoke configs).  Cuts DP gradient traffic 4x vs f32 / 2x vs bf16.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads(grads, error):
    """Returns (int8_tree, scales_tree, new_error_tree)."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - qi.astype(jnp.float32) * scale
        return qi, scale, new_e

    out = jax.tree.map(q, grads, error)
    qs = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    sc = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    er = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return qs, sc, er


def decompress_grads(qs, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)
