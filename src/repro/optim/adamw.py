"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Moments are kept in f32 regardless of parameter dtype (mixed-precision
training discipline, mirroring the paper's 16-bit-storage / 32-bit-reduce
split); the update is computed in f32 and cast back to the parameter dtype.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["mu", "nu", "count"], meta_fields=[],
)
@dataclasses.dataclass
class AdamWState:
    mu: dict
    nu: dict
    count: jax.Array


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        count=jnp.zeros((), jnp.int32),
    )


def cosine_lr(step, *, peak: float = 3e-4, warmup: int = 100, total: int = 10000,
              floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = peak * (step + 1) / max(warmup, 1)   # step 0 takes a real (small) step
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    ))


def adamw_update(params, grads, state: AdamWState, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float | None = 1.0):
    count = state.count + 1
    if clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m, n):
        step = (m / c1) / (jnp.sqrt(n / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu, nu, count)
