"""Optimizers (pure JAX, no optax): AdamW + cosine schedule + global clipping,
plus an int8 error-feedback gradient-compression wrapper for the DP axis."""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update, cosine_lr  # noqa: F401
from repro.optim.compress import compress_grads, decompress_grads  # noqa: F401
