"""Unit tests for the dry-run/roofline machinery (parsers, extrapolation,
probe configs, analytic memory model).  The launcher itself needs 512 fake
devices and is exercised by the sweep (results/dryrun) + a subprocess test."""

import importlib.util
import json
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_dryrun_module():
    """Import dryrun WITHOUT triggering its XLA_FLAGS (already-initialized
    jax in this process ignores the env var, so importing is safe)."""
    spec = importlib.util.spec_from_file_location(
        "dryrun_under_test", os.path.join(REPO, "src/repro/launch/dryrun.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def dr():
    return _load_dryrun_module()


HLO_SAMPLE = """
  %ar0 = f32[128]{0} all-reduce(f32[128]{0} %x), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
  %ag = bf16[4,256]{1,0} all-gather(bf16[4,16]{1,0} %y), channel_id=2, replica_groups=[16,16]<=[256], dimensions={1}
  %cp = bf16[1,38,1536]{2,1,0} collective-permute(%z), channel_id=3, source_target_pairs={{0,16},{1,17}}
  %ars = (f32[2]{0}, f32[4]{0}) all-reduce(%a, %b), channel_id=4, replica_groups={{0,1,2,3}}, to_apply=%add
  %start = f32[64]{0} all-reduce-start(f32[64]{0} %w), channel_id=5, replica_groups=[2,128]<=[256]
  %done = f32[64]{0} all-reduce-done(f32[64]{0} %start)
"""


def test_parse_collectives_ops_and_bytes(dr):
    out = dr.parse_collectives(HLO_SAMPLE, 256)
    by = out["by_op"]
    assert by["all-reduce"]["count"] == 3          # ar0, tuple ars, start (not done)
    assert by["all-gather"]["count"] == 1
    assert by["collective-permute"]["count"] == 1
    # tuple all-reduce bytes = 2*4 + 4*4
    assert by["all-reduce"]["bytes"] == 128 * 4 + (2 * 4 + 4 * 4) + 64 * 4
    assert by["all-gather"]["bytes"] == 4 * 256 * 2
    assert by["collective-permute"]["bytes"] == 38 * 1536 * 2


def test_parse_collectives_ring_factors(dr):
    out = dr.parse_collectives(
        "%ar = f32[100]{0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%a\n", 4)
    # group=4 => 2*(4-1)/4 = 1.5x
    assert abs(out["total_link_bytes"] - 400 * 1.5) < 1e-6
    out = dr.parse_collectives(
        "%cp = f32[100]{0} collective-permute(%x), source_target_pairs={{0,1}}\n", 4)
    assert out["total_link_bytes"] == 400.0        # permute: 1x


def test_group_size_formats(dr):
    assert dr._group_size("replica_groups=[16,16]<=[256]", 256) == 16
    assert dr._group_size("replica_groups={{0,1,2,3,4,5,6,7}}", 256) == 8
    assert dr._group_size("no groups here", 256) == 256


def test_extrapolation_is_exact_for_affine(dr):
    c1 = {"flops": 10.0, "bytes": 7.0}
    c2 = {"flops": 14.0, "bytes": 9.0}
    out = dr._extrapolate(c1, c2, 10)
    assert out["flops"] == 10 + 9 * 4 and out["bytes"] == 7 + 9 * 2


def test_probe_config_shapes():
    from repro.configs import get_config
    from repro.models.model import probe_config
    cfg = get_config("gemma3_12b")
    p1 = probe_config(cfg, 1, 32768)
    assert p1.n_layers == len(cfg.period) == 6
    assert p1.unroll and p1.inner_unroll and not p1.remat
    assert p1.attn_block == 8192
    p2 = probe_config(cfg, 2, 4096)
    assert p2.n_layers == 12


def test_lm_memory_estimate_orders_of_magnitude():
    from repro.configs import get_config
    from repro.launch.roofline_model import lm_cell_memory_estimate
    from repro.models.model import SHAPES

    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen2_1_5b")
    est = lm_cell_memory_estimate(cfg, SHAPES["smoke_decode"], mesh)
    # single fake device, smoke decode: params dominate; 1.5B * 2B ~ 3.1GB
    assert 2.5e9 < est["est_params_bytes"] < 4.5e9
    assert est["est_hbm_traffic_bytes"] >= est["est_params_bytes"]


def test_sweep_artifacts_complete_and_clean():
    """The committed dry-run sweep must cover all 86 cells with 0 errors:
    40 LM cells x 2 meshes + 3 stencil cells x 2 meshes."""
    d = os.path.join(REPO, "results/dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run sweep not present")
    cells = [json.load(open(os.path.join(d, f)))
             for f in os.listdir(d) if f.endswith(".json")]
    assert len(cells) >= 86
    assert sum(c.get("status") == "error" for c in cells) == 0
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    assert len(skipped) == 16      # 8 full-attention archs x long_500k x 2 meshes
    for c in ok:
        assert c["t_bound_s"] > 0
        assert c["dominant"] in ("compute", "memory", "collective")
        # multi-pod proof: every ok cell exists in both mesh variants unless skipped
    meshes = {(c["arch"], c["shape"]): set() for c in ok}
    for c in ok:
        meshes[(c["arch"], c["shape"])].add(c["mesh"])
    for key, ms in meshes.items():
        assert ms == {"16x16", "2x16x16"}, (key, ms)


def test_production_mesh_shapes(subproc):
    subproc("""
        from repro.launch.mesh import make_production_mesh, fabric_shape
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert fabric_shape(m1) == (1, 16, 16)
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert fabric_shape(m2) == (2, 16, 16)
        print("OK")
    """, n_devices=512)


def test_mesh_helpers_single_device():
    from repro.launch.mesh import make_mesh_for_devices, fabric_shape
    m = make_mesh_for_devices(1)
    assert dict(m.shape) == {"data": 1, "model": 1}
    assert fabric_shape(m) == (1, 1, 1)
