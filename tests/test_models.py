"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, one train step + prefill + decode on CPU, asserting shapes and
finiteness.  Plus behavioural tests for the layer zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ALIASES, get_config, get_smoke
from repro.models import model as M, transformer
from repro.optim.adamw import adamw_init


def _batch_for(cfg, B=2, T=32, seed=1):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    t_text = T - (cfg.n_frontend_tokens if cfg.frontend == "vlm" else 0)
    batch = {
        "tokens": jax.random.randint(k1, (B, t_text), 0, cfg.vocab),
        "labels": jax.random.randint(k2, (B, t_text), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, t_text), jnp.float32),
    }
    if cfg.frontend == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            k3, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(k3, (B, T, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow   # ~6 min of XLA compiles across the arch matrix
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    opt = adamw_init(params)
    step = jax.jit(M.make_train_step(cfg))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert int(o2.count) == 1
    # params actually changed (bf16 embeds may round a tiny step away, so
    # require change in at least one leaf rather than a specific one)
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke(arch)
    B, T = 2, 32
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    batch.pop("labels"), batch.pop("loss_mask")
    caches = M.init_caches(cfg, B, T)
    logits, caches = jax.jit(M.make_prefill_step(cfg, M.SHAPES["smoke_prefill"]))(
        params, batch, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    serve = jax.jit(M.make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, caches = serve(params, {"token": tok}, caches)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_match_assignment(arch):
    """The exact published numbers from the assignment sheet."""
    cfg = get_config(arch)
    sheet = {
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
        "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "rwkv6_7b": (32, 4096, None, None, 14336, 65536),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, None, 151936),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch]
    L, d, H, kv, ff, V = sheet
    assert cfg.n_layers == L
    assert cfg.d_model == d
    if H is not None:
        assert cfg.n_heads == H
        assert cfg.n_kv_heads == kv
    if ff is not None:
        assert (cfg.d_ff == ff) or (cfg.d_ff_expert == ff)
    assert cfg.vocab == V
    # period divides depth
    assert cfg.n_layers % len(cfg.period) == 0


def test_moe_configs():
    q = get_config("qwen2_moe_a2_7b")
    assert (q.n_experts, q.top_k, q.n_shared_experts, q.d_ff_expert) == (60, 4, 4, 1408)
    g = get_config("grok_1_314b")
    assert (g.n_experts, g.top_k) == (8, 2)
    j = get_config("jamba_v0_1_52b")
    assert (j.n_experts, j.top_k) == (16, 2)
    # jamba: 1 attention per 8 layers, MoE every other layer
    kinds = [s.kind for s in j.period]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum(s.moe for s in j.period) == 4


def test_grok_param_count_is_314b_scale():
    n = M.n_params(get_config("grok_1_314b"))
    assert 250e9 < n < 400e9, n
    n_act = M.n_active_params(get_config("grok_1_314b"))
    assert n_act < 0.45 * n  # top-2 of 8 experts


def test_aliases_resolve():
    for alias in ALIASES:
        assert get_config(alias).name


def test_long500k_gate():
    from repro.models.model import SHAPES, cell_is_supported
    long = SHAPES["long_500k"]
    ok, _ = cell_is_supported(get_config("rwkv6_7b"), long)
    assert ok
    ok, _ = cell_is_supported(get_config("jamba_v0_1_52b"), long)
    assert ok
    for arch in ("deepseek_7b", "gemma3_12b", "whisper_large_v3", "grok_1_314b"):
        ok, why = cell_is_supported(get_config(arch), long)
        assert not ok and "full-attention" in why


# ---------------------------------------------------------------------------
# Layer-level behaviour
# ---------------------------------------------------------------------------

def test_rwkv_chunked_matches_recurrent():
    from repro.models import rwkv6
    from repro.models.param import init_tree
    d, hs, B, T = 32, 16, 2, 24
    p = init_tree(rwkv6.build_params(d, hs, 64, dtype=jnp.float32),
                  jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32) * 0.3
    o_rec, (s_rec, _) = rwkv6.time_mix(p, x, head_size=hs, chunked=False)
    o_chk, (s_chk, _) = rwkv6.time_mix(p, x, head_size=hs, chunked=True, chunk=8)
    np.testing.assert_allclose(np.asarray(o_rec), np.asarray(o_chk), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_rec), np.asarray(s_chk), rtol=2e-3, atol=2e-3)


def test_mamba_scan_matches_stepwise_decode():
    from repro.models import mamba
    from repro.models.param import init_tree
    d, B, T = 16, 2, 6
    p = init_tree(mamba.build_params(d, d_state=4, d_conv=3, expand=2,
                                     dtype=jnp.float32), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d), jnp.float32) * 0.3
    o_full, (s_full, c_full) = mamba.mamba_apply(p, x)
    # stepwise
    s = jnp.zeros((B, 2 * d, 4), jnp.float32)
    c = jnp.zeros((B, 2, 2 * d), jnp.float32)
    outs = []
    for t in range(T):
        o, (s, c) = mamba.mamba_decode(p, x[:, t : t + 1], s, c)
        outs.append(o)
    o_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_matches_direct():
    from repro.models.layers import flash_attention
    B, T, K, G, D = 2, 32, 2, 3, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, T, K, G, D), jnp.float32)
    k = jax.random.normal(k2, (B, T, K, D), jnp.float32)
    v = jax.random.normal(k3, (B, T, K, D), jnp.float32)
    pos = jnp.arange(T)
    o_small = flash_attention(q, k, v, pos, pos, block=8)
    o_big = flash_attention(q, k, v, pos, pos, block=64)
    np.testing.assert_allclose(np.asarray(o_small), np.asarray(o_big),
                               rtol=1e-5, atol=1e-5)
    # direct reference
    import math as _m
    s = jnp.einsum("btkgd,bskd->btkgs", q, k) / _m.sqrt(D)
    mask = pos[None, :] <= pos[:, None]
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    ref = jnp.einsum("btkgs,bskd->btkgd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(o_small), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_masks_out_far_context():
    from repro.models.layers import flash_attention
    B, T, K, G, D = 1, 16, 1, 1, 8
    q = jnp.ones((B, T, K, G, D))
    k = jnp.ones((B, T, K, D))
    # distinctive values: v[t] = t
    v = jnp.broadcast_to(jnp.arange(T, dtype=jnp.float32)[None, :, None, None],
                         (B, T, K, D))
    pos = jnp.arange(T)
    o = flash_attention(q, k, v, pos, pos, window=4, block=8)
    # at t=15 with window 4: attends positions 12..15 => mean = 13.5
    np.testing.assert_allclose(float(o[0, 15, 0, 0, 0]), 13.5, rtol=1e-3)


def test_moe_capacity_and_balance_loss():
    from repro.models import moe as moe_lib
    from repro.models.param import init_tree
    d, E, k = 16, 8, 2
    p = init_tree(moe_lib.build_params(d, E, 32, dtype=jnp.float32),
                  jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d), jnp.float32)
    out, aux = moe_lib.moe_apply(p, x, n_experts=E, top_k=k, group_size=16)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound at balance


def test_prefix_lm_mask_paligemma():
    """Image tokens must see each other bidirectionally."""
    cfg = get_smoke("paligemma_3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 16
    batch = _batch_for(cfg, B=B, T=T)
    logits, _, _ = transformer.forward(cfg, params, batch, mode="train")
    # flip a LATE image patch; prefix-LM lets it influence EARLY image rows'
    # representations only through bidirectional prefix attention
    pe2 = batch["patch_embeds"].at[:, -1].add(10.0)
    logits2, _, _ = transformer.forward(cfg, params, {**batch, "patch_embeds": pe2},
                                        mode="train")
    d0 = np.abs(np.asarray(logits2 - logits, np.float32))[0, 0].max()
    assert d0 > 1e-4  # first image row changed => bidirectional prefix confirmed
