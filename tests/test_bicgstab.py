"""BiCGStab / CG solver behaviour tests (single-device oracle paths)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import bicgstab, precision, stencil


def _problem(shape, seed=0, kind="random"):
    k = jax.random.PRNGKey(seed)
    if kind == "random":
        cf = stencil.random_nonsymmetric(k, shape)
    elif kind == "poisson":
        cf = stencil.poisson(shape)
    else:
        cf = stencil.convection_diffusion(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(seed + 1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    return cf, x_true, b


@pytest.mark.parametrize("kind", ["random", "poisson", "convdiff"])
def test_converges_to_true_solution(kind):
    cf, x_true, b = _problem((6, 6, 6), kind=kind)
    res = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=400)
    assert bool(res.converged)
    assert not bool(res.breakdown)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true), rtol=2e-4, atol=2e-4)


def test_matches_numpy_solve():
    cf, _, b = _problem((4, 4, 4), seed=7)
    res = bicgstab.solve_ref(cf, b, tol=1e-10, maxiter=400)
    A = stencil.to_dense(cf)
    x_np = np.linalg.solve(A, np.asarray(b, np.float64).ravel()).reshape(b.shape)
    np.testing.assert_allclose(np.asarray(res.x), x_np, rtol=1e-4, atol=1e-4)


def test_true_residual_decreases():
    cf, _, b = _problem((6, 6, 6))
    res = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=400)
    r = np.asarray(b) - np.asarray(stencil.apply_ref(cf, res.x))
    assert np.linalg.norm(r) / np.linalg.norm(np.asarray(b)) < 1e-6


def test_zero_rhs_converges_immediately():
    cf, _, _ = _problem((4, 4, 4))
    res = bicgstab.solve_ref(cf, jnp.zeros((4, 4, 4), jnp.float32), tol=1e-8)
    assert bool(res.converged)
    assert int(res.iterations) == 0
    assert np.abs(np.asarray(res.x)).max() == 0.0


def test_warm_start_reduces_iterations():
    cf, x_true, b = _problem((6, 6, 6))
    cold = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=400)
    warm = bicgstab.solve_ref(
        cf, b, x0=x_true + 1e-4 * jnp.ones_like(x_true), tol=1e-8, maxiter=400
    )
    assert int(warm.iterations) < int(cold.iterations)


def test_history_mode_matches_loop_mode():
    cf, _, b = _problem((5, 5, 5))
    loop = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=60)
    hist = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=60, record_history=True)
    assert bool(hist.converged)
    np.testing.assert_allclose(np.asarray(loop.x), np.asarray(hist.x), rtol=1e-5, atol=1e-6)
    h = np.asarray(hist.history)
    # history is monotone-ish at the tail and frozen after convergence
    assert h[-1] <= 1e-8


def test_mixed_precision_true_residual_plateaus():
    """Paper Fig. 9: the 16-bit recurrence keeps 'converging' but the TRUE
    residual plateaus near 16-bit machine precision."""
    cf, _, b = _problem((8, 8, 8), kind="convdiff")
    res = bicgstab.solve_ref(
        cf, b.astype(jnp.bfloat16), tol=1e-12, maxiter=200, policy=precision.MIXED
    )
    r = np.asarray(b, np.float64) - np.asarray(
        stencil.apply_ref(cf.astype(jnp.float32), res.x.astype(jnp.float32)), np.float64
    )
    true_rel = np.linalg.norm(r) / np.linalg.norm(np.asarray(b, np.float64))
    # bf16 has ~8 mantissa bits => plateau well above f32 but solve is usable
    assert 1e-7 < true_rel < 5e-2


def test_iterative_refinement_recovers_f32_accuracy():
    cf, x_true, b = _problem((6, 6, 6), kind="convdiff")
    x, rels = bicgstab.solve_refined(
        cf, b, outer_iters=4, inner_maxiter=60, inner_policy=precision.MIXED
    )
    rels = np.asarray(rels)
    assert rels[-1] < 1e-5          # recovered past the bf16 plateau
    assert (np.diff(np.log10(rels + 1e-30)) < 0).all()  # monotone improvement
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_true), rtol=1e-3, atol=1e-3)


def test_cg_on_spd_poisson():
    cf, x_true, b = _problem((6, 6, 6), kind="poisson")
    res = bicgstab.cg_ref(cf, b, tol=1e-8, maxiter=400)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true), rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(3, 7), seed=st.integers(0, 2**30),
    dominance=st.floats(1.1, 3.0),
)
def test_property_solver_beats_tolerance(n, seed, dominance):
    """For any diagonally-dominant stencil system, the solver's exit residual
    honors the requested tolerance (system invariant)."""
    shape = (n, n, n)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(seed), shape, dominance=dominance)
    x_true = jax.random.normal(jax.random.PRNGKey(seed + 1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    res = bicgstab.solve_ref(cf, b, tol=1e-6, maxiter=500)
    assert bool(res.converged)
    r = np.asarray(b) - np.asarray(stencil.apply_ref(cf, res.x))
    assert np.linalg.norm(r) <= 5e-5 * max(np.linalg.norm(np.asarray(b)), 1e-30)
