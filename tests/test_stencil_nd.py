"""Generalized Pallas stencil kernel (kernels/stencil_nd) tests: every spec
against the jnp oracle, chunking equivalence, and the distributed drop-in."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stencil
from repro.kernels.stencil_nd import stencil_apply, stencil_nd_ref


def _tol(dtype):
    return (dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16
            else dict(rtol=2e-5, atol=2e-5))


@pytest.mark.parametrize("specname", ["star7", "star13", "star25", "box27"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(specname, dtype):
    spec = stencil.get_spec(specname)
    shape = (6, 7, 8)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape,
                                     dtype=dtype, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32).astype(dtype)
    u_k = stencil_apply(cf, v, spec=spec)
    u_r = stencil_nd_ref(v, [cf.diags[n] for n in spec.names], spec.offsets)
    np.testing.assert_allclose(np.asarray(u_k, np.float32),
                               np.asarray(u_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("specname", ["star25", "box27"])
def test_kernel_matches_core_apply(specname):
    """The kernel must agree with the solver's own oracle (core.stencil)."""
    spec = stencil.get_spec(specname)
    shape = (5, 6, 16)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(2), shape, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32)
    u_k = stencil_apply(cf, v, spec=spec)
    u_c = stencil.apply_ref(cf, v)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_c),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("specname", ["star13", "box27"])
def test_zc_chunking_equivalence(specname):
    """Different VMEM chunkings must give identical results (r-deep windows)."""
    from repro.kernels.stencil_nd.kernel import stencil_nd_pallas
    spec = stencil.get_spec(specname)
    shape = (4, 5, 32)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(4), shape, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(5), shape, jnp.float32)
    vp = jnp.pad(v, spec.radius)
    cl = [cf.diags[n] for n in spec.names]
    outs = [stencil_nd_pallas(vp, cl, spec.offsets, radius=spec.radius, zc=zc)
            for zc in (32, 16, 8, 4)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=0, atol=0)


def test_stencil7_alias_is_generic_kernel():
    """kernels.stencil7 must be a one-file deprecation shim re-exporting the
    r=1 star specialization of stencil_nd (satellite: the old package's
    kernel/ops/ref bodies are gone)."""
    from repro.kernels import stencil7, stencil_nd
    assert stencil7.__file__.endswith("stencil7.py")   # module, not package
    for name in ("stencil7_apply", "stencil7_ref", "stencil7_pallas",
                 "pallas_local_apply", "stencil7_dot", "stencil7_two_dots",
                 "ORDER", "pick_zc", "VMEM_BUDGET_BYTES"):
        assert hasattr(stencil7, name), name          # legacy surface intact
    shape = (4, 4, 8)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(6), shape)
    v = jax.random.normal(jax.random.PRNGKey(7), shape, jnp.float32)
    u7 = stencil7.stencil7_apply(cf, v)
    und = stencil_nd.stencil_apply(cf, v, spec=stencil.STAR7)
    np.testing.assert_allclose(np.asarray(u7), np.asarray(und), rtol=0, atol=0)
    u_ref = stencil7.stencil7_ref(v, [cf.diags[n] for n in stencil7.ORDER])
    np.testing.assert_allclose(np.asarray(u7), np.asarray(u_ref),
                               rtol=1e-5, atol=1e-5)


def test_pick_zc_budget_scales_with_radius():
    from repro.kernels.stencil_nd.ops import pick_zc
    # same block: a deeper/wider stencil must not pick a LARGER chunk
    zc1 = pick_zc(64, 64, 256, 4, radius=1, n_coeffs=6, budget=2 ** 22)
    zc4 = pick_zc(64, 64, 256, 4, radius=4, n_coeffs=24, budget=2 ** 22)
    assert zc4 <= zc1
    assert 256 % zc4 == 0


@pytest.mark.parametrize("specname", ["star13", "box27"])
@pytest.mark.slow
def test_pallas_local_apply_in_distributed_solver(subproc, specname):
    """solve_distributed with the generic kernel as apply_impl == jnp path,
    on a depth-2 (star13) and corner-carrying (box27) halo."""
    subproc(f"""
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil, bicgstab, precision
        from repro.kernels.stencil_nd import pallas_local_apply
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        spec = stencil.get_spec({specname!r})
        shape = (8, 8, 8)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        res = bicgstab.solve_distributed(
            mesh, cf, b, tol=1e-8, maxiter=300, policy=precision.F32,
            apply_impl=functools.partial(pallas_local_apply, interpret=True))
        assert bool(res.converged), res
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """, n_devices=4)
