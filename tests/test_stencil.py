"""Stencil operator unit + property tests (oracle: dense matrix)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import precision, stencil


@pytest.mark.parametrize("shape", [(4, 4, 4), (6, 5, 7), (5, 4), (3, 9)])
def test_apply_matches_dense(shape):
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
    v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    u = stencil.apply_ref(cf, v)
    A = stencil.to_dense(cf)
    u_dense = (A @ np.asarray(v, np.float64).ravel()).reshape(shape)
    np.testing.assert_allclose(np.asarray(u), u_dense, rtol=2e-5, atol=2e-5)


def test_poisson_is_symmetric():
    cf = stencil.poisson((4, 5, 3))
    A = stencil.to_dense(cf)
    np.testing.assert_allclose(A, A.T, rtol=0, atol=0)
    # unit diagonal after Jacobi preconditioning
    np.testing.assert_allclose(np.diag(A), 1.0)
    # SPD: eigenvalues positive
    w = np.linalg.eigvalsh(A)
    assert w.min() > 0


def test_convection_diffusion_nonsymmetric_dominant():
    cf = stencil.convection_diffusion((4, 4, 4), peclet=5.0)
    A = stencil.to_dense(cf)
    assert not np.allclose(A, A.T)
    off = np.abs(A - np.eye(A.shape[0])).sum(axis=1)
    assert off.max() < 1.0  # strict diagonal dominance of the preconditioned A


def test_random_stencil_diagonally_dominant():
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(3), (5, 5, 5), dominance=1.25)
    A = stencil.to_dense(cf)
    off = np.abs(A - np.eye(A.shape[0])).sum(axis=1)
    assert off.max() <= 1.0 / 1.25 + 1e-6


def test_zero_dirichlet_boundary():
    """A row at the mesh corner must not read wrapped-around values."""
    shape = (3, 3, 3)
    cf = stencil.StencilCoeffs(
        {n: jnp.full(shape, 1.0, jnp.float32) for n in stencil.DIAGS_3D}
    )
    v = jnp.zeros(shape, jnp.float32).at[2, 2, 2].set(1.0)
    u = stencil.apply_ref(cf, v)
    # corner (0,0,0) is 3 hops away; all its neighbors are zero => u = v = 0
    assert u[0, 0, 0] == 0.0
    # direct neighbor of the impulse picks it up through one diagonal
    assert u[1, 2, 2] == 1.0  # xp coefficient reads v[2,2,2]


@settings(max_examples=25, deadline=None)
@given(
    nx=st.integers(2, 6), ny=st.integers(2, 6), nz=st.integers(2, 6),
    seed=st.integers(0, 2**30),
)
def test_apply_linearity_property(nx, ny, nz, seed):
    """A(av + bw) == a Av + b Aw for arbitrary shapes/seeds (f32)."""
    shape = (nx, ny, nz)
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    cf = stencil.random_nonsymmetric(k1, shape)
    v = jax.random.normal(k2, shape, jnp.float32)
    w = jax.random.normal(k3, shape, jnp.float32)
    lhs = stencil.apply_ref(cf, 2.0 * v - 3.0 * w)
    rhs = 2.0 * stencil.apply_ref(cf, v) - 3.0 * stencil.apply_ref(cf, w)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


def test_mixed_policy_dot_accumulates_in_f32():
    """Paper §IV-3: 16-bit multiplies, 32-bit adds. The MIXED dot must carry
    an f32 accumulator (FMAC semantics: unrounded products into a wide add)
    and be near-exact when inputs are bf16-representable."""
    n = 1 << 16
    a = jnp.full((n,), 1.0, jnp.bfloat16)      # exactly representable
    mixed = precision.MIXED.dot(a, a)          # f32 accumulation
    pure = precision.BF16_PURE.dot(a, a)
    assert mixed.dtype == jnp.float32
    assert pure.dtype == jnp.bfloat16          # the ablation keeps a 16-bit reduce
    assert abs(float(mixed) - n) / n < 1e-3
    # f32 accumulation resolves steps bf16 cannot even represent
    b = jnp.asarray(np.linspace(0.5, 1.5, n), jnp.bfloat16)
    exact = float(np.asarray(b, np.float64) @ np.asarray(b, np.float64))
    assert abs(float(precision.MIXED.dot(b, b)) - exact) / exact < 1e-3


def test_flops_words_per_point_match_table1():
    # Table I: Matvec x2 contributes 24 of 44 ops/meshpoint/iter => 12 per SpMV.
    assert stencil.flops_per_point(3) == 12
    assert stencil.words_per_point(3) == 8
