"""Stencil-family (StencilSpec) unit tests: shape algebra, dense oracle
agreement for star25/box27, generators, and accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stencil


def test_spec_shapes_and_names():
    assert stencil.STAR7.n_points == 7
    assert stencil.STAR13.n_points == 13
    assert stencil.STAR25.n_points == 25
    assert stencil.BOX27.n_points == 27
    # the radius-1 star keeps the paper's exact names and order
    assert stencil.STAR7.names == stencil.DIAGS_3D
    assert stencil.StencilSpec("star", 1, 2).names == stencil.DIAGS_2D
    # registry round trip
    for name in ("star7", "star13", "star25", "box27"):
        assert stencil.get_spec(name).name == name
    with pytest.raises(KeyError):
        stencil.get_spec("star999")


def test_offset_names_round_trip():
    for spec in (stencil.STAR7, stencil.STAR13, stencil.STAR25, stencil.BOX27):
        for off, name in zip(spec.offsets, spec.names):
            assert stencil.name_offset(name, spec.ndim) == off
        # spec reconstruction from names alone
        assert stencil.spec_of(spec.names, spec.ndim) == spec


def test_coeffs_carry_their_spec():
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), (4, 4, 4),
                                     spec=stencil.BOX27)
    assert cf.spec == stencil.BOX27
    cf7 = stencil.poisson((4, 4, 4))
    assert cf7.spec == stencil.STAR7


@pytest.mark.parametrize("specname", ["star13", "star25", "box27"])
def test_apply_matches_dense_oracle(specname):
    """Acceptance: star25 and box27 apply == dense matvec to tolerance."""
    spec = stencil.get_spec(specname)
    shape = (5, 6, 7)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    u = stencil.apply_ref(cf, v)
    A = stencil.to_dense(cf)
    u_dense = (A @ np.asarray(v, np.float64).ravel()).reshape(shape)
    np.testing.assert_allclose(np.asarray(u), u_dense, rtol=2e-5, atol=2e-5)


def test_zero_dirichlet_deep_offsets():
    """A star25 arm reaching past the mesh edge must contribute zero."""
    shape = (5, 5, 5)
    cf = stencil.StencilCoeffs({
        n: jnp.full(shape, 1.0, jnp.float32) for n in stencil.STAR25.names})
    v = jnp.zeros(shape, jnp.float32).at[4, 4, 4].set(1.0)
    u = stencil.apply_ref(cf, v)
    # (0,4,4) reads x+1..x+4: x+4 lands on the impulse
    assert u[0, 4, 4] == 1.0
    # (0,0,0) has no arm reaching (4,4,4) (star has no diagonal coupling)
    assert u[0, 0, 0] == 0.0


def test_box27_couples_corners():
    shape = (3, 3, 3)
    cf = stencil.StencilCoeffs({
        n: jnp.full(shape, 1.0, jnp.float32) for n in stencil.BOX27.names})
    v = jnp.zeros(shape, jnp.float32).at[1, 1, 1].set(1.0)
    u = stencil.apply_ref(cf, v)
    # every cell of the 3x3x3 cube sees the center impulse exactly once
    np.testing.assert_allclose(np.asarray(u), np.ones(shape))


def test_poisson_generalizes_symmetric_dominant():
    for spec in (stencil.STAR13, stencil.BOX27):
        cf = stencil.poisson((4, 4, 4), spec=spec)
        A = stencil.to_dense(cf)
        np.testing.assert_allclose(A, A.T, rtol=0, atol=0)
        np.testing.assert_allclose(np.diag(A), 1.0)
        off = np.abs(A - np.eye(A.shape[0])).sum(axis=1)
        assert off.max() <= 1.0 + 1e-6


def test_high_order_star_is_dominant_and_has_fd_signs():
    cf = stencil.high_order_star((5, 5, 5), radius=4, dominance=1.25)
    assert cf.spec == stencil.STAR25
    A = stencil.to_dense(cf)
    off = np.abs(A - np.eye(A.shape[0])).sum(axis=1)
    assert off.max() <= 1.0 / 1.25 + 1e-6
    # 8th-order FD weights alternate sign along an arm: -, +, -, +
    xp1 = float(cf.diags["xp"][2, 2, 2])
    xp2 = float(cf.diags["xp2"][2, 2, 2])
    xp3 = float(cf.diags["xp3"][2, 2, 2])
    assert xp1 < 0 < xp2 and xp3 < 0
    with pytest.raises(ValueError):
        stencil.high_order_star((5, 5, 5), radius=9)


def test_solver_converges_on_family():
    """star25 and box27 systems solve end-to-end with the reference solver."""
    from repro.core import bicgstab
    shape = (6, 6, 6)
    for spec in (stencil.STAR25, stencil.BOX27):
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(2), shape, spec=spec)
        x_true = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        res = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=400)
        assert bool(res.converged), spec.name
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                   rtol=2e-4, atol=2e-4)


def test_spec_accounting():
    assert stencil.spec_flops_per_point(stencil.STAR7) == stencil.flops_per_point(3)
    assert stencil.spec_flops_per_point(stencil.STAR25) == 48
    assert stencil.spec_flops_per_point(stencil.BOX27) == 52
    assert stencil.spec_words_per_point(stencil.STAR7) == stencil.words_per_point(3)
    # depth-r halo moves r-thick slabs; box corners ride on padded slabs
    block = (8, 8, 8)
    star = stencil.halo_words_per_spmv(stencil.STAR13, block)
    assert star == 2 * (2 * 8 * 8) * 2
    box = stencil.halo_words_per_spmv(stencil.BOX27, block)
    assert box == 2 * 8 * 8 + 2 * 10 * 8  # y slabs carry the x halo


def test_family_cell_configs():
    from repro.configs.stencil_box27 import BOX27_CELLS, ops_per_meshpoint_box27
    from repro.configs.stencil_star25_seismic import (
        SEISMIC_CELLS, ops_per_meshpoint_star25)
    for cells in (SEISMIC_CELLS, BOX27_CELLS):
        for cell in cells.values():
            spec = stencil.get_spec(cell.stencil)
            t = (ops_per_meshpoint_star25() if spec.pattern == "star"
                 else ops_per_meshpoint_box27())
            assert t["total"] == 2 * stencil.spec_flops_per_point(spec) + 8 + 12
    tuned = SEISMIC_CELLS["rtm_chip_tuned"]
    assert tuned.autotune and tuned.backend == "pallas"
    assert not SEISMIC_CELLS["rtm_chip"].autotune  # default stays off
