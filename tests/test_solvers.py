"""Solver-registry tests: CG/BiCGStab result parity, history/breakdown
flags, the pipelined single-reduction variants (trajectory match + the
1-AllReduce-per-iteration HLO assertion), and the distributed CG path
across the stencil family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bicgstab, stencil
from repro.core.solvers import SOLVERS, SolveResult, get_solver

# pipelined_cg maintains w = A r purely by recurrence, which bounds its
# attainable f32 accuracy near sqrt(eps) — test it at tolerances it can meet
SOLVER_TOL = {"pipelined_cg": 1e-5}


def test_registry_contents():
    assert set(SOLVERS) == {"bicgstab", "cg", "pipelined_bicgstab",
                            "pipelined_cg"}
    with pytest.raises(KeyError, match="unknown solver"):
        get_solver("gmres")


def _poisson_problem(shape, seed=1):
    cf = stencil.poisson(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return cf, x_true, stencil.rhs_for_solution(cf, x_true)


@pytest.mark.parametrize("solver", sorted(SOLVERS))
def test_solvers_return_uniform_solve_result(solver):
    """Every registry entry — generic and pipelined — has full SolveResult
    parity: breakdown flag and residual history included."""
    tol = SOLVER_TOL.get(solver, 1e-8)
    cf, x_true, b = _poisson_problem((6, 6, 6))
    res = bicgstab.solve_ref(cf, b, tol=tol, maxiter=100, solver=solver,
                             record_history=True)
    assert isinstance(res, SolveResult)
    assert bool(res.converged)
    assert not bool(res.breakdown)
    assert res.history is not None and res.history.shape == (100,)
    hist = np.asarray(res.history)
    n = int(res.iterations)
    assert hist[n - 1] <= tol                   # converged where it says
    assert (hist[n:] == hist[n - 1]).all()      # frozen after convergence
    xtol = 2e-3 if solver == "pipelined_cg" else 2e-4
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                               rtol=xtol, atol=xtol)


def test_cg_matches_numpy_solve():
    cf, _, b = _poisson_problem((4, 4, 4), seed=7)
    res = bicgstab.solve_ref(cf, b, solver="cg", tol=1e-10, maxiter=400)
    A = stencil.to_dense(cf)
    x_np = np.linalg.solve(A, np.asarray(b, np.float64).ravel()).reshape(b.shape)
    np.testing.assert_allclose(np.asarray(res.x), x_np, rtol=1e-4, atol=1e-4)


def test_cg_zero_rhs_converges_immediately():
    cf, _, _ = _poisson_problem((4, 4, 4))
    res = bicgstab.solve_ref(cf, jnp.zeros((4, 4, 4), jnp.float32),
                             solver="cg", tol=1e-8)
    assert bool(res.converged)
    assert int(res.iterations) == 0
    assert not bool(res.breakdown)


def test_cg_warm_start_reduces_iterations():
    cf, x_true, b = _poisson_problem((8, 8, 8))
    cold = bicgstab.solve_ref(cf, b, solver="cg", tol=1e-8, maxiter=400)
    warm = bicgstab.solve_ref(
        cf, b, x0=x_true + 1e-4 * jnp.ones_like(x_true),
        solver="cg", tol=1e-8, maxiter=400)
    assert int(warm.iterations) < int(cold.iterations)
    assert bool(warm.converged)


# ---------------------------------------------------------------------------
# Pipelined single-reduction solvers (default tier — ISSUE 5 satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_name", ["star7", "box27"])
def test_pipelined_bicgstab_matches_generic_trajectory(spec_name):
    """Acceptance: the re-anchored single-reduction BiCGStab reproduces the
    generic loop's residual trajectory (lag-1: its convergence check reads
    the carried residual) on star7 and box27, and solves the system."""
    spec = stencil.get_spec(spec_name)
    shape = (8, 8, 8)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    g = bicgstab.solve_ref(cf, b, tol=1e-7, maxiter=100, solver="bicgstab",
                           record_history=True)
    p = bicgstab.solve_ref(cf, b, tol=1e-7, maxiter=100,
                           solver="pipelined_bicgstab", record_history=True)
    assert bool(p.converged) and not bool(p.breakdown)
    assert int(p.iterations) <= int(g.iterations) + 2
    hg, hp = np.asarray(g.history), np.asarray(p.history)
    n = min(int(g.iterations), int(p.iterations) - 1)
    # histories share index semantics across solvers (the pipelined loops
    # realign their lag-1 recording): entry k = residual after iteration k+1
    # atol floors the comparison where both trajectories sit at rounding
    np.testing.assert_allclose(hp[:n], hg[:n], rtol=5e-2, atol=1e-8)
    np.testing.assert_allclose(np.asarray(p.x), np.asarray(x_true),
                               rtol=2e-4, atol=2e-4)


def test_pipelined_cg_matches_generic_trajectory():
    """Ghysels-Vanroose pipelined CG tracks generic CG (lag-1) down to its
    f32 attainable-accuracy floor on the SPD Poisson operator."""
    cf, x_true, b = _poisson_problem((8, 8, 8))
    g = bicgstab.solve_ref(cf, b, tol=1e-5, maxiter=200, solver="cg",
                           record_history=True)
    p = bicgstab.solve_ref(cf, b, tol=1e-5, maxiter=200,
                           solver="pipelined_cg", record_history=True)
    assert bool(p.converged) and not bool(p.breakdown)
    assert int(p.iterations) <= int(g.iterations) + 2
    hg, hp = np.asarray(g.history), np.asarray(p.history)
    n = min(int(g.iterations), int(p.iterations) - 1, 15)
    np.testing.assert_allclose(hp[:n], hg[:n], rtol=5e-2, atol=1e-6)


@pytest.mark.parametrize("solver,precond", [
    # Jacobi on the raw variable-diagonal problem (real registry work);
    # pipelined_cg gets Chebyshev on SPD Poisson instead — a polynomial in
    # A commutes with it and preserves the symmetry CG's theory needs
    ("pipelined_bicgstab", "jacobi"),
    ("pipelined_cg", "chebyshev"),
])
def test_pipelined_solvers_accept_preconditioning(solver, precond):
    """Right preconditioning wraps the pipelined loops like the generic
    ones — same hat-system plumbing, collective schedule untouched."""
    shape = (6, 6, 8)
    if precond == "jacobi":
        cf = stencil.heterogeneous_poisson(jax.random.PRNGKey(3), shape)
    else:
        cf = stencil.poisson(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(4), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    res = bicgstab.solve_ref(cf, b, tol=1e-5, maxiter=400, solver=solver,
                             precond=precond)
    assert bool(res.converged), solver
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                               rtol=2e-3, atol=2e-3)


def test_pipelined_allreduce_count_is_1_per_iteration(subproc):
    """Acceptance: a whole jitted distributed solve lowers to exactly
    1 + maxiter-independent AllReduce counts — one fused setup reduction
    plus ONE AllReduce in the loop body for the pipelined solvers (vs 3
    for fused BiCGStab, 2 for CG)."""
    subproc("""
        import jax, jax.numpy as jnp
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        shape = (8, 8, 8)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        b = jnp.ones(shape, jnp.float32)
        expected = {'bicgstab': 1 + 3, 'cg': 1 + 2,
                    'pipelined_bicgstab': 1 + 1, 'pipelined_cg': 1 + 1}
        for solver, want in expected.items():
            f = lambda c, bb, s=solver: bicgstab.solve_distributed(
                mesh, c, bb, maxiter=7, policy=precision.F32, solver=s)
            text = jax.jit(f).lower(cf, b).as_text()
            n = text.count('all_reduce') + text.count('all-reduce')
            assert n == want, (solver, n, want)
        print('OK')
    """, n_devices=4)


def test_distributed_pipelined_matches_spmd_trajectory(subproc):
    """The distributed pipelined BiCGStab reproduces the distributed
    generic trajectory on a 2x2 fabric (spmd backend, both schedules)."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        shape = (8, 8, 6)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        g = bicgstab.solve_distributed(mesh, cf, b, tol=1e-7, maxiter=60,
                                       policy=precision.F32,
                                       record_history=True)
        runs = {}
        for schedule in ('blocking', 'overlap'):
            r = bicgstab.solve_distributed(mesh, cf, b, tol=1e-7, maxiter=60,
                                           policy=precision.F32,
                                           solver='pipelined_bicgstab',
                                           schedule=schedule,
                                           record_history=True)
            assert bool(r.converged) and not bool(r.breakdown), schedule
            runs[schedule] = r
        # the halo schedule must not change the pipelined solve at all
        assert np.array_equal(np.asarray(runs['blocking'].x),
                              np.asarray(runs['overlap'].x))
        p = runs['overlap']
        hg, hp = np.asarray(g.history), np.asarray(p.history)
        n = min(int(g.iterations), int(p.iterations) - 1)
        np.testing.assert_allclose(hp[:n], hg[:n], rtol=5e-2, atol=1e-8)
        np.testing.assert_allclose(np.asarray(p.x), np.asarray(x_true),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """, n_devices=4)


@pytest.mark.slow
def test_distributed_cg_across_family(subproc):
    """Distributed CG (2 fused AllReduces/iter) agrees with the dense oracle
    for star7/star25/box27 SPD problems, in f32 and the mixed policy."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)     # 2 x 4 fabric
        shape = (8, 16, 6)                  # local blocks fit radius 4
        for name in ("star7", "star25", "box27"):
            spec = stencil.get_spec(name)
            cf = stencil.poisson(shape, spec=spec)
            x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
            b = stencil.rhs_for_solution(cf, x_true)
            A = stencil.to_dense(cf)
            x_np = np.linalg.solve(A, np.asarray(b, np.float64).ravel())
            res = bicgstab.solve_distributed(mesh, cf, b, solver="cg",
                                             tol=1e-8, maxiter=600,
                                             policy=precision.F32)
            assert bool(res.converged) and not bool(res.breakdown), name
            np.testing.assert_allclose(np.asarray(res.x, np.float64).ravel(),
                                       x_np, rtol=2e-4, atol=2e-4)
            res16 = bicgstab.solve_distributed(mesh, cf, b.astype(jnp.bfloat16),
                                               solver="cg", tol=1e-2,
                                               maxiter=600,
                                               policy=precision.MIXED)
            assert bool(res16.converged), name
            np.testing.assert_allclose(np.asarray(res16.x, np.float64).ravel(),
                                       x_np, rtol=0.15, atol=0.15)
        print('OK')
    """)
