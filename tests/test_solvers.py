"""Solver-registry tests: CG/BiCGStab result parity, history/breakdown
flags, and the distributed CG path across the stencil family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bicgstab, stencil
from repro.core.solvers import SOLVERS, SolveResult, get_solver


def test_registry_contents():
    assert set(SOLVERS) == {"bicgstab", "cg"}
    with pytest.raises(KeyError, match="unknown solver"):
        get_solver("gmres")


def _poisson_problem(shape, seed=1):
    cf = stencil.poisson(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return cf, x_true, stencil.rhs_for_solution(cf, x_true)


@pytest.mark.parametrize("solver", sorted(SOLVERS))
def test_solvers_return_uniform_solve_result(solver):
    """Satellite bugfix: cg has full SolveResult parity with BiCGStab —
    breakdown flag and residual history included."""
    cf, x_true, b = _poisson_problem((6, 6, 6))
    res = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=100, solver=solver,
                             record_history=True)
    assert isinstance(res, SolveResult)
    assert bool(res.converged)
    assert not bool(res.breakdown)
    assert res.history is not None and res.history.shape == (100,)
    hist = np.asarray(res.history)
    n = int(res.iterations)
    assert hist[n - 1] <= 1e-8                  # converged where it says
    assert (hist[n:] == hist[n - 1]).all()      # frozen after convergence
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                               rtol=2e-4, atol=2e-4)


def test_cg_matches_numpy_solve():
    cf, _, b = _poisson_problem((4, 4, 4), seed=7)
    res = bicgstab.solve_ref(cf, b, solver="cg", tol=1e-10, maxiter=400)
    A = stencil.to_dense(cf)
    x_np = np.linalg.solve(A, np.asarray(b, np.float64).ravel()).reshape(b.shape)
    np.testing.assert_allclose(np.asarray(res.x), x_np, rtol=1e-4, atol=1e-4)


def test_cg_zero_rhs_converges_immediately():
    cf, _, _ = _poisson_problem((4, 4, 4))
    res = bicgstab.solve_ref(cf, jnp.zeros((4, 4, 4), jnp.float32),
                             solver="cg", tol=1e-8)
    assert bool(res.converged)
    assert int(res.iterations) == 0
    assert not bool(res.breakdown)


def test_cg_warm_start_reduces_iterations():
    cf, x_true, b = _poisson_problem((8, 8, 8))
    cold = bicgstab.solve_ref(cf, b, solver="cg", tol=1e-8, maxiter=400)
    warm = bicgstab.solve_ref(
        cf, b, x0=x_true + 1e-4 * jnp.ones_like(x_true),
        solver="cg", tol=1e-8, maxiter=400)
    assert int(warm.iterations) < int(cold.iterations)
    assert bool(warm.converged)


@pytest.mark.slow
def test_distributed_cg_across_family(subproc):
    """Distributed CG (2 fused AllReduces/iter) agrees with the dense oracle
    for star7/star25/box27 SPD problems, in f32 and the mixed policy."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)     # 2 x 4 fabric
        shape = (8, 16, 6)                  # local blocks fit radius 4
        for name in ("star7", "star25", "box27"):
            spec = stencil.get_spec(name)
            cf = stencil.poisson(shape, spec=spec)
            x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
            b = stencil.rhs_for_solution(cf, x_true)
            A = stencil.to_dense(cf)
            x_np = np.linalg.solve(A, np.asarray(b, np.float64).ravel())
            res = bicgstab.solve_distributed(mesh, cf, b, solver="cg",
                                             tol=1e-8, maxiter=600,
                                             policy=precision.F32)
            assert bool(res.converged) and not bool(res.breakdown), name
            np.testing.assert_allclose(np.asarray(res.x, np.float64).ravel(),
                                       x_np, rtol=2e-4, atol=2e-4)
            res16 = bicgstab.solve_distributed(mesh, cf, b.astype(jnp.bfloat16),
                                               solver="cg", tol=1e-2,
                                               maxiter=600,
                                               policy=precision.MIXED)
            assert bool(res16.converged), name
            np.testing.assert_allclose(np.asarray(res16.x, np.float64).ravel(),
                                       x_np, rtol=0.15, atol=0.15)
        print('OK')
    """)
