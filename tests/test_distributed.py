"""Distributed (shard_map) solver tests — run in subprocesses with 8 fake
devices so the main pytest process keeps a single CpuDevice."""

import pytest

pytestmark = pytest.mark.slow   # every test here spawns 8-device subprocesses


def test_distributed_apply_matches_ref(subproc):
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil
        from repro.core.halo import global_apply
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        shape = (8, 8, 6)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        u_ref = stencil.apply_ref(cf, v)
        for overlap in (True, False):
            u = global_apply(mesh, cf, v, overlap=overlap)
            np.testing.assert_allclose(np.asarray(u), np.asarray(u_ref), rtol=1e-5, atol=1e-5)
        print('OK')
    """)


def test_distributed_solve_matches_ref(subproc):
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil, bicgstab, precision
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        shape = (8, 8, 6)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        for fused in (True, False):
            res = bicgstab.solve_distributed(mesh, cf, b, tol=1e-8, maxiter=300,
                                             policy=precision.F32, fused_reductions=fused)
            assert bool(res.converged) and not bool(res.breakdown)
            np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                       rtol=2e-4, atol=2e-4)
        print('OK')
    """)


def test_multipod_z_split_solve(subproc):
    """3-axis mesh: pod axis slabs Z with its own halo exchange."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil, bicgstab, precision
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8, pods=2)   # (pod=2, data=2, model=2)
        shape = (4, 4, 8)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        res = bicgstab.solve_distributed(mesh, cf, b, tol=1e-8, maxiter=300,
                                         policy=precision.F32)
        assert bool(res.converged)
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """)


def test_depth_r_halo_apply_matches_ref(subproc):
    """Acceptance: the SPMD depth-r halo path (star13 r=2, star25 r=4, box27
    corners) agrees with the single-device reference on an 8-device mesh."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil
        from repro.core.halo import global_apply
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)    # 2 x 4 fabric
        shape = (8, 16, 6)                 # local blocks (4, 4, 6) >= radius 4
        for name in ("star13", "star25", "box27"):
            spec = stencil.get_spec(name)
            cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
            v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
            u_ref = stencil.apply_ref(cf, v)
            for overlap in (True, False):
                u = global_apply(mesh, cf, v, overlap=overlap)
                np.testing.assert_allclose(np.asarray(u), np.asarray(u_ref),
                                           rtol=1e-5, atol=1e-5, err_msg=name)
        print('OK')
    """)


def test_depth_r_halo_multipod_z_split(subproc):
    """Depth-2 and corner halos across a 3-axis mesh (pod axis slabs Z)."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil
        from repro.core.halo import global_apply
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8, pods=2)
        shape = (4, 4, 8)
        for name in ("star13", "box27"):
            spec = stencil.get_spec(name)
            cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
            v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
            u_ref = stencil.apply_ref(cf, v)
            u = global_apply(mesh, cf, v)
            np.testing.assert_allclose(np.asarray(u), np.asarray(u_ref),
                                       rtol=1e-5, atol=1e-5, err_msg=name)
        print('OK')
    """)


def test_distributed_solve_star25_and_box27(subproc):
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil, bicgstab, precision
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)    # 2 x 2 fabric
        shape = (8, 8, 6)
        for spec, gen in ((stencil.STAR25, lambda: stencil.high_order_star(shape, 4)),
                          (stencil.BOX27, lambda: stencil.random_nonsymmetric(
                               jax.random.PRNGKey(0), shape, spec=stencil.BOX27))):
            cf = gen()
            x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
            b = stencil.rhs_for_solution(cf, x_true)
            res = bicgstab.solve_distributed(mesh, cf, b, tol=1e-8, maxiter=300,
                                             policy=precision.F32)
            assert bool(res.converged), spec.name
            np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                       rtol=2e-4, atol=2e-4, err_msg=spec.name)
        print('OK')
    """, n_devices=4)


def test_halo_depth_exceeding_block_raises(subproc):
    """radius > local block extent must fail loudly, not corrupt."""
    subproc("""
        import jax, jax.numpy as jnp
        from repro.core import stencil
        from repro.core.halo import global_apply
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)    # 2 x 4: y blocks of 2 < radius 4
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), (8, 8, 6),
                                         spec=stencil.STAR25)
        v = jnp.ones((8, 8, 6), jnp.float32)
        try:
            global_apply(mesh, cf, v)
        except ValueError as e:
            assert 'halo depth' in str(e), e
            print('OK')
        else:
            raise SystemExit('expected ValueError')
    """)


def test_fused_reductions_reduce_allreduce_count(subproc):
    """Beyond-paper claim: 3 fused vs 5 separate AllReduces per iteration."""
    subproc("""
        import jax, jax.numpy as jnp
        from repro.core import stencil, bicgstab, precision
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), (8, 8, 4))
        b = jnp.ones((8, 8, 4), jnp.float32)
        def n_allreduce(fused):
            f = lambda c, bb: bicgstab.solve_distributed(
                mesh, c, bb, maxiter=10, policy=precision.F32, fused_reductions=fused)
            return jax.jit(f).lower(cf, b).as_text().count('all_reduce')
        nf, ns = n_allreduce(True), n_allreduce(False)
        assert nf < ns, (nf, ns)
        print('OK', nf, ns)
    """)


def test_distributed_mixed_precision(subproc):
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil, bicgstab, precision
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        shape = (8, 8, 6)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        res = bicgstab.solve_distributed(mesh, cf, b.astype(jnp.bfloat16),
                                         tol=1e-8, maxiter=300, policy=precision.MIXED)
        err = np.abs(np.asarray(res.x, np.float32) - np.asarray(x_true)).max()
        assert err < 0.1, err   # bf16 plateau accuracy
        print('OK')
    """)
