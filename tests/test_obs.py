"""Observability layer: spans, metrics registry, run manifests.

The two load-bearing guarantees tested here:

* enabling observability is invisible to the compiler — the lowered HLO of
  a distributed solve is bit-identical with obs on vs off (spans live at
  trace time, metric emission is tracer-guarded);
* the collective counts the launch path emits into ``events.jsonl`` match
  the HLO ground truth recomputed from the same lowering, across
  {bicgstab, pipelined_bicgstab} x {blocking, overlap} on a real
  multi-device fabric.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.obs import manifest, metrics, trace

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- spans --


class TestSpans:
    def test_disabled_returns_noop_singleton(self):
        trace.disable()
        s1, s2 = trace.span("a"), trace.span("b", k=1)
        assert s1 is s2  # one shared _NullSpan: no per-call allocation
        with s1 as sp:
            assert sp.block(123) == 123
            sp.set(x=1)
        assert trace.events() == []

    def test_nesting_and_monotonic_timing(self):
        trace.enable()
        with trace.span("outer"):
            with trace.span("inner", k=2):
                time.sleep(0.002)
        evs = trace.events()
        # inner exits (and records) first
        assert [e["name"] for e in evs] == ["inner", "outer"]
        inner, outer = evs
        assert inner["parent"] == "outer" and inner["depth"] == 1
        assert outer["parent"] is None and outer["depth"] == 0
        assert inner["attrs"]["k"] == 2
        assert outer["ts_us"] <= inner["ts_us"]
        assert inner["dur_us"] > 0
        assert outer["dur_us"] >= inner["dur_us"]

    def test_set_attaches_attrs_after_entry(self):
        trace.enable()
        with trace.span("s") as sp:
            sp.set(result="ok")
        assert trace.events()[-1]["attrs"]["result"] == "ok"

    def test_chrome_trace_document(self):
        trace.enable()
        with trace.span("x", tag="t"):
            pass
        doc = trace.chrome_trace()
        assert doc["traceEvents"]
        ev = doc["traceEvents"][0]
        assert ev["ph"] == "X" and ev["name"] == "x"
        assert {"ts", "dur", "pid", "tid", "args"} <= set(ev)
        assert ev["args"]["tag"] == "t"
        json.dumps(doc)  # Perfetto needs plain JSON

    def test_block_syncs_only_when_enabled(self):
        import jax.numpy as jnp

        trace.enable(sync=True)
        with trace.span("s") as sp:
            out = sp.block(jnp.ones(3) * 2)
        np.testing.assert_array_equal(np.asarray(out), 2.0)


# -------------------------------------------------------------- metrics --


class TestMetrics:
    def test_counters_gauges_histograms(self):
        metrics.counter("c").inc()
        metrics.counter("c").inc(2)
        metrics.gauge("g").set(3.5)
        h = metrics.histogram("h")
        for v in (1.0, 2.0, 3.0):
            h.observe(v)
        snap = metrics.snapshot()
        assert snap["counters"]["c"] == 3
        assert snap["gauges"]["g"] == 3.5
        hs = snap["histograms"]["h"]
        assert hs["count"] == 3 and hs["min"] == 1.0 and hs["max"] == 3.0

    def test_reset_isolation_between_tests(self):
        # the autouse conftest fixture wiped the previous test's registry
        snap = metrics.snapshot()
        assert "c" not in snap["counters"] and "g" not in snap["gauges"]

    def test_events_are_ordered_dicts(self):
        metrics.event("e1", a=1)
        metrics.event("e2", kind="payload-field")  # 'kind' as a data field
        evs = metrics.events()
        assert evs[-2]["event"] == "e1" and evs[-2]["a"] == 1
        assert evs[-1]["event"] == "e2" and evs[-1]["kind"] == "payload-field"

    def test_count_collectives(self):
        text = "all-reduce x all_reduce y collective-permute collective_permute"
        assert metrics.count_collectives(text) == {
            "allreduce_total": 2, "ppermute_total": 2}

    def test_is_concrete_rejects_tracers(self):
        import jax
        import jax.numpy as jnp

        assert metrics.is_concrete(np.ones(3))
        assert metrics.is_concrete(jnp.ones(3))
        seen = []

        @jax.jit
        def f(x):
            seen.append(metrics.is_concrete(x))
            return x
        f(jnp.ones(3))
        assert seen == [False]

    def test_emit_solve_metrics_end_to_end(self):
        import jax

        from repro.core import bicgstab, precision, stencil
        from repro.core.solvers.common import emit_solve_metrics
        from repro.launch.mesh import make_mesh_for_devices

        shape = (8, 8, 8)
        cf = stencil.poisson(shape)
        x_true = jax.random.normal(jax.random.PRNGKey(0), shape)
        b = stencil.rhs_for_solution(cf, x_true)
        res = bicgstab.solve_distributed(
            make_mesh_for_devices(), cf, b, tol=1e-5, maxiter=100,
            policy=precision.F32)
        emit_solve_metrics(res, wall_s=0.1, solver="bicgstab")
        snap = metrics.snapshot()
        assert snap["counters"]["solve.total"] == 1
        assert snap["counters"]["solve.rhs_converged"] == 1
        assert snap["gauges"]["solve.iterations_max"] >= 1
        ev = [e for e in metrics.events() if e["event"] == "solve"][-1]
        assert ev["solver"] == "bicgstab" and ev["converged"] == [True]


# --------------------------------------------------- HLO invariance -----


class TestHLOInvariance:
    def test_obs_enabled_hlo_is_bit_identical(self):
        """The acceptance guarantee: spans/metrics insert no ops."""
        import jax
        import jax.numpy as jnp

        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices

        mesh = make_mesh_for_devices()
        shape = (8, 8, 8)
        cf = stencil.poisson(shape)
        b = jnp.ones(shape, jnp.float32)

        def f(c, v):
            return bicgstab.solve_distributed(
                mesh, c, v, tol=0.0, maxiter=4, policy=precision.F32,
                schedule="overlap")

        trace.disable()
        off = jax.jit(f).lower(cf, b).as_text()
        trace.enable(sync=True)
        on = jax.jit(f).lower(cf, b).as_text()
        assert off == on


# ------------------------------------------------------------ manifests --


class TestManifest:
    def test_round_trip(self, tmp_path):
        trace.enable()
        with trace.span("unit.work"):
            pass
        metrics.counter("unit.count").inc()
        run_dir = str(tmp_path / "run")
        ctx = manifest.start_run("unittest", config={"a": 1, "shape": (4, 4)},
                                 run_dir=run_dir)
        man = manifest.finish_run(ctx, extra={"note": "x"})

        assert manifest.validate_manifest(man) == []
        loaded = manifest.load_manifest(run_dir)
        assert manifest.validate_manifest(loaded) == []
        assert loaded["kind"] == "unittest"
        assert loaded["config"] == {"a": 1, "shape": [4, 4]}
        assert loaded["note"] == "x"
        assert loaded["metrics"]["counters"]["unit.count"] == 1

        with open(os.path.join(run_dir, "events.jsonl")) as f:
            evs = [json.loads(line) for line in f]
        assert evs[0]["event"] == "run_start"
        assert evs[-1]["event"] == "run_finish"
        assert evs[0]["run_id"] == man["run_id"]

        with open(os.path.join(run_dir, "trace.json")) as f:
            doc = json.load(f)
        assert any(e["name"] == "unit.work" for e in doc["traceEvents"])

    def test_validate_catches_missing_fields(self):
        problems = manifest.validate_manifest({"schema": "bogus"})
        assert any("run_id" in p for p in problems)
        assert any("bogus" in p for p in problems)

    def test_benchmark_bundle(self, tmp_path):
        rec = {"schema": "repro.benchmark.v1", "generated_by": "test",
               "cells": [1, 2]}
        d = manifest.write_benchmark_bundle("demo", rec, root=str(tmp_path))
        man = manifest.load_manifest(d)
        assert manifest.validate_manifest(man) == []
        assert man["kind"] == "bench-demo" and man["benchmark"] == "demo"
        with open(os.path.join(d, "record.json")) as f:
            assert json.load(f) == rec


# ------------------------------- emitted counts vs HLO ground truth -----


_COUNTS_SNIPPET = """
import json, os, tempfile
import jax, jax.numpy as jnp
from repro.core import bicgstab, precision, stencil
from repro.launch.mesh import make_mesh_for_devices
from repro.obs import manifest, metrics, trace

trace.enable()
mesh = make_mesh_for_devices(8)
shape = (8, 8, 8)
cf = stencil.poisson(shape)
b = jnp.ones(shape, jnp.float32)
run_dir = tempfile.mkdtemp()
ctx = manifest.start_run("hlo-counts", run_dir=run_dir)
truth = {}
for solver in ("bicgstab", "pipelined_bicgstab"):
    for schedule in ("blocking", "overlap"):
        def f(c, v, solver=solver, schedule=schedule):
            return bicgstab.solve_distributed(
                mesh, c, v, tol=0.0, maxiter=6, policy=precision.F32,
                solver=solver, schedule=schedule)
        text = jax.jit(f).lower(cf, b).as_text()
        truth[f"{solver}/{schedule}"] = metrics.count_collectives(text)
        metrics.record_collectives(text, solver=solver, schedule=schedule)
manifest.finish_run(ctx)
with open(os.path.join(run_dir, "events.jsonl")) as f:
    events = [json.loads(line) for line in f if line.strip()]
emitted = {
    f"{e['solver']}/{e['schedule']}": {
        "allreduce_total": e["allreduce_total"],
        "ppermute_total": e["ppermute_total"]}
    for e in events if e["event"] == "collectives"}
print(json.dumps({"truth": truth, "emitted": emitted}))
"""


def test_emitted_collective_counts_match_hlo(subproc):
    """events.jsonl collective counts == HLO ground truth, and the totals
    match the analytic schedule: 1 setup AllReduce + per-iteration
    {bicgstab: 3, pipelined_bicgstab: 1}; ppermutes schedule-independent."""
    out = subproc(_COUNTS_SNIPPET, n_devices=8)
    data = json.loads(out.strip().splitlines()[-1])
    truth, emitted = data["truth"], data["emitted"]

    assert emitted == truth  # what we logged IS what the compiler lowered
    want_allreduce = {"bicgstab": 1 + 3, "pipelined_bicgstab": 1 + 1}
    for solver, want in want_allreduce.items():
        for schedule in ("blocking", "overlap"):
            c = emitted[f"{solver}/{schedule}"]
            assert c["allreduce_total"] == want, (solver, schedule, c)
            assert c["ppermute_total"] > 0, (solver, schedule, c)
        # overlap restructures the halo exchange but must not add messages
        assert (emitted[f"{solver}/blocking"]["ppermute_total"]
                == emitted[f"{solver}/overlap"]["ppermute_total"])


# --------------------------------------------------------- compare_runs --


class TestCompareRuns:
    def _bundle(self, path, iters):
        metrics.reset()
        trace.reset()
        metrics.gauge("solve.iterations_max").set(iters)
        ctx = manifest.start_run("solve", run_dir=str(path))
        manifest.finish_run(ctx)

    def _compare(self, *argv):
        return subprocess.run(
            [sys.executable, os.path.join(REPO, "scripts", "compare_runs.py"),
             *map(str, argv)],
            capture_output=True, text=True, cwd=REPO)

    def test_equal_runs_exit_zero(self, tmp_path):
        self._bundle(tmp_path / "base", 10)
        out = self._compare(tmp_path / "base", tmp_path / "base")
        assert out.returncode == 0, out.stdout + out.stderr

    def test_injected_iteration_regression_exits_nonzero(self, tmp_path):
        self._bundle(tmp_path / "base", 10)
        self._bundle(tmp_path / "cand", 15)
        out = self._compare(tmp_path / "base", tmp_path / "cand")
        assert out.returncode == 1, out.stdout + out.stderr
        assert "REGRESSION" in out.stdout
        assert "solve.iterations_max" in out.stderr

    def test_threshold_waives_regression(self, tmp_path):
        self._bundle(tmp_path / "base", 10)
        self._bundle(tmp_path / "cand", 15)
        out = self._compare(tmp_path / "base", tmp_path / "cand",
                            "--max-iter-increase-pct", "60")
        assert out.returncode == 0, out.stdout + out.stderr
