"""Pallas kernel tests: shape/dtype sweeps + hypothesis properties, all in
interpret=True mode against the pure-jnp oracles (ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import stencil
from repro.kernels import fused_iter as fi
from repro.kernels.fused_iter import ref as R
from repro.kernels.stencil7 import ORDER, stencil7_apply, stencil7_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(4, 4, 4), (6, 7, 8), (3, 5, 16), (8, 8, 32), (1, 1, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stencil7_kernel_matches_ref(shape, dtype):
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, dtype=dtype)
    v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32).astype(dtype)
    u_k = stencil7_apply(cf, v)
    u_r = stencil7_ref(v, [cf.diags[n] for n in ORDER])
    np.testing.assert_allclose(np.asarray(u_k, np.float32), np.asarray(u_r, np.float32),
                               **_tol(dtype))


def test_stencil7_kernel_matches_core_apply():
    """The kernel must agree with the solver's own oracle (core.stencil)."""
    shape = (5, 6, 16)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(2), shape)
    v = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32)
    u_k = stencil7_apply(cf, v)
    u_c = stencil.apply_ref(cf, v)
    np.testing.assert_allclose(np.asarray(u_k), np.asarray(u_c), rtol=1e-5, atol=1e-5)


def test_stencil7_zc_chunking_equivalence():
    """Different VMEM chunkings must give identical results."""
    from repro.kernels.stencil7 import stencil7_pallas
    shape = (4, 5, 32)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(4), shape)
    v = jax.random.normal(jax.random.PRNGKey(5), shape, jnp.float32)
    vp = jnp.pad(v, ((1, 1), (1, 1), (1, 1)))
    cl = [cf.diags[n] for n in ORDER]
    outs = [stencil7_pallas(vp, cl, zc=zc) for zc in (32, 16, 8, 4)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(
    nx=st.integers(1, 6), ny=st.integers(1, 6),
    logz=st.integers(0, 5), seed=st.integers(0, 2**30),
    bf16=st.booleans(),
)
def test_stencil7_property_sweep(nx, ny, logz, seed, bf16):
    shape = (nx, ny, 2 ** logz)
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(seed), shape, dtype=dtype)
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), shape, jnp.float32).astype(dtype)
    u_k = stencil7_apply(cf, v)
    u_r = stencil7_ref(v, [cf.diags[n] for n in ORDER])
    np.testing.assert_allclose(np.asarray(u_k, np.float32), np.asarray(u_r, np.float32),
                               **_tol(dtype))


def _vecs(n, dtype, seed=0, k=7):
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    return [jax.random.normal(kk, (n,), jnp.float32).astype(dtype) for kk in keys]


@pytest.mark.parametrize("n", [1, 100, 128, 1000, 4096, 65536 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_q_dots(n, dtype):
    r, s, y, *_ = _vecs(n, dtype)
    alpha = jnp.float32(0.37)
    q1, qy1, yy1 = fi.update_q_dots(alpha, r, s, y)
    q2, qy2, yy2 = R.update_q_dots_ref(alpha, r, s, y)
    np.testing.assert_allclose(np.asarray(q1, np.float32), np.asarray(q2, np.float32),
                               **_tol(dtype))
    # bf16 product rounding differs across XLA versions (the kernel widens
    # before the multiply, the oracle rounds after); bf16 eps is ~3.9e-3, so
    # the partial-dot tolerance must sit above one ulp of the products.
    np.testing.assert_allclose(float(qy1), float(qy2), rtol=8e-3, atol=8e-3 * n ** 0.5)
    np.testing.assert_allclose(float(yy1), float(yy2), rtol=8e-3, atol=8e-3 * n ** 0.5)


@pytest.mark.parametrize("n", [100, 1000, 65536 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_xr_dots(n, dtype):
    x, p, q, y, r0, *_ = _vecs(n, dtype, seed=1)
    alpha, omega = jnp.float32(0.3), jnp.float32(-0.7)
    o1 = fi.update_xr_dots(alpha, omega, x, p, q, y, r0)
    o2 = R.update_xr_dots_ref(alpha, omega, x, p, q, y, r0)
    for a, b in zip(o1[:2], o2[:2]):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   **_tol(dtype))
    for a, b in zip(o1[2:], o2[2:]):
        # see test_fused_update_q_dots: tolerance must exceed bf16 ulp
        np.testing.assert_allclose(float(a), float(b), rtol=8e-3, atol=8e-3 * n ** 0.5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_p(dtype):
    r, p, s, *_ = _vecs(777, dtype, seed=2)
    beta, omega = jnp.float32(1.2), jnp.float32(0.4)
    p1 = fi.update_p(beta, omega, r, p, s)
    p2 = R.update_p_ref(beta, omega, r, p, s)
    np.testing.assert_allclose(np.asarray(p1, np.float32), np.asarray(p2, np.float32),
                               **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**30))
def test_dot_mixed_property(n, seed):
    a, b, *_ = _vecs(n, jnp.bfloat16, seed=seed, k=2)
    got = float(fi.dot_mixed(a, b))
    want = float(np.asarray(a, np.float64) @ np.asarray(b, np.float64))
    # bf16 products, f32 accumulation: error ~ sqrt(n) * eps_bf16 * |a||b|
    scale = float(np.linalg.norm(np.asarray(a, np.float64)) *
                  np.linalg.norm(np.asarray(b, np.float64))) + 1e-6
    assert abs(got - want) <= 0.02 * scale


def test_pallas_solver_integration():
    """Full BiCGStab with the fused kernels as the AXPY/dot engine."""
    from repro.core import bicgstab

    shape = (5, 5, 8)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(7), shape)
    x_true = jax.random.normal(jax.random.PRNGKey(8), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)

    def axpy(a, xx, yy):  # y + a*x via the fused p-update kernel (beta=a path)
        return fi.update_p(a, jnp.float32(0.0), yy, xx, xx)

    res = bicgstab.solve_ref(cf, b, tol=1e-7, maxiter=300)
    assert bool(res.converged)
    # kernel-built q/x/r updates reproduce one solver iteration exactly
    r = b
    p = b
    s = stencil.apply_ref(cf, p)
    alpha = jnp.float32(float(res.x.sum()) * 0 + 0.5)
    q1, qy, yy = fi.update_q_dots(alpha, r, s, stencil.apply_ref(cf, r))
    q2 = r - 0.5 * s
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 4, 8), (5, 6, 16), (3, 3, 4)])
def test_stencil7_dot_epilogue(shape):
    """Fused SpMV + <r0, s> epilogue (§Perf v3 schedule) vs oracles."""
    from repro.kernels.stencil_nd.fused import stencil7_dot, stencil7_two_dots
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
    p = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    r0 = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
    s, r0s = stencil7_dot(cf, p, r0)
    s_ref = stencil7_ref(p, [cf.diags[n] for n in ORDER])
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(r0s), float(jnp.vdot(r0, s_ref)), rtol=1e-4, atol=1e-4)
    y, qy, yy = stencil7_two_dots(cf, p)
    np.testing.assert_allclose(float(qy), float(jnp.vdot(p, s_ref)), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(float(yy), float(jnp.vdot(s_ref, s_ref)), rtol=1e-4, atol=1e-3)


@pytest.mark.slow
def test_pallas_local_apply_in_distributed_solver(subproc):
    """solve_distributed with the Pallas kernel as apply_impl == jnp path."""
    subproc("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.core import stencil, bicgstab, precision
        from repro.kernels.stencil7 import pallas_local_apply
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        shape = (8, 8, 8)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        res = bicgstab.solve_distributed(
            mesh, cf, b, tol=1e-8, maxiter=300, policy=precision.F32,
            apply_impl=functools.partial(pallas_local_apply, interpret=True))
        assert bool(res.converged), res
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """, n_devices=4)


def test_fused_schedule_full_solve():
    """End-to-end BiCGStab through the v3 fused-kernel schedule converges to
    the same solution as the reference solver."""
    from repro.core import bicgstab
    shape = (6, 6, 8)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(11), shape)
    x_true = jax.random.normal(jax.random.PRNGKey(12), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    res = bicgstab.solve_ref_fused(cf, b, tol=1e-7, maxiter=100)
    assert bool(res.converged), float(res.rel_residual)
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                               rtol=5e-4, atol=5e-4)


def test_fp8_coefficients_with_refinement():
    """§Perf stencil v4: fp8-e4m3 coefficient storage for the fast sweeps,
    f32 refinement residuals recover full accuracy."""
    from repro.core import bicgstab, stencil as st_
    shape = (8, 8, 8)
    cf32 = st_.convection_diffusion(shape, peclet=3.0)
    x_true = jax.random.normal(jax.random.PRNGKey(13), shape, jnp.float32)
    b = st_.rhs_for_solution(cf32, x_true)
    # fp8 round-trip of the six diagonals (what the fused SpMV would read)
    cf8 = st_.StencilCoeffs({
        k: v.astype(jnp.float8_e4m3fn).astype(jnp.bfloat16)
        for k, v in cf32.diags.items()})
    x = jnp.zeros(shape, jnp.float32)
    bn = float(jnp.linalg.norm(b))
    rels = []
    for _ in range(6):
        r = b - st_.apply_ref(cf32, x)           # TRUE residual: f32 A
        rels.append(float(jnp.linalg.norm(r)) / bn)
        from repro.core.precision import MIXED
        d = bicgstab.solve_ref(cf8, r.astype(jnp.bfloat16), tol=1e-3,
                               maxiter=60, policy=MIXED)
        x = x + d.x.astype(jnp.float32)
    rels.append(float(jnp.linalg.norm(b - st_.apply_ref(cf32, x))) / bn)
    assert rels[-1] < 1e-4, rels                 # fp8 inner, f32-grade outer
    assert all(b2 < a2 for a2, b2 in zip(rels[:3], rels[1:4]))  # monotone early
