"""CFD application subsystem tests: registry routing, reference vs SPMD
agreement, transient checkpoint/restore determinism, the channel scenario,
the f64 policy registration, and the f32 clamp-before-cast bugfix."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps.cfd import (
    CavityConfig, CFDConfig, SolverOptions, TransientConfig, centerline_u,
    run_transient, simple_step, solve_steady, to_staggered,
)
from repro.apps.cfd.grid import cell_state, from_staggered
from repro.core import precision
from repro.launch.mesh import make_mesh_for_devices


# ---------------------------------------------------------------------------
# Legacy surface + registry routing
# ---------------------------------------------------------------------------

def test_legacy_reexport_forwards_to_apps():
    from repro.core import simple_cfd

    assert simple_cfd.simple_step is simple_step
    assert simple_cfd.CavityConfig is CFDConfig
    assert simple_cfd.centerline_u is centerline_u


def test_staggered_roundtrip():
    u = jnp.arange(12.0).reshape(4, 3) + 1.0
    v = jnp.arange(12.0).reshape(3, 4) + 1.0
    us, vs = to_staggered(u[:3, :], v[:, :3])
    assert us.shape == (4, 3) and vs.shape == (3, 4)
    uc, vc = from_staggered(us, vs)
    np.testing.assert_array_equal(np.asarray(uc), np.asarray(u[:3, :]))
    np.testing.assert_array_equal(np.asarray(vc), np.asarray(v[:, :3]))


def test_spmd_backend_matches_reference_on_degenerate_fabric():
    """On a degenerate 1-device fabric the SPMD backend (halo gathers reduce
    to zero-padding, psums to the identity) must agree with the reference
    backend; the real 2x2-fabric agreement test is the slow variant below."""
    cfg = CFDConfig(n=12, reynolds=100.0, outer_iters=30, tol=1e-12)
    mesh = make_mesh_for_devices(1)
    ur, vr, pr, hr = solve_steady(cfg, SolverOptions(backend="reference"))
    us, vs, ps, hs = solve_steady(cfg, SolverOptions(backend="spmd"), mesh)
    assert hr[0] == pytest.approx(hs[0], rel=1e-6)
    np.testing.assert_allclose(np.asarray(ur), np.asarray(us), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vr), np.asarray(vs), atol=1e-5)


def test_raw_rows_with_jacobi_converge_to_same_flow():
    """normalize=False hands the solver the raw aP-diagonal rows; the
    registry Jacobi preconditioner then does the paper's normalization job
    and the flow converges to the same field.  (Relies on the hat-space
    warm-start translation in core/precond.py — without it the truncated
    inner solves restart from D^-1 u every outer iteration and stall.)"""
    cfg = CFDConfig(n=12, reynolds=100.0, outer_iters=120, tol=1e-5)
    u0, v0, p0, h0 = solve_steady(cfg, SolverOptions())
    u1, v1, p1, h1 = solve_steady(
        cfg, SolverOptions(precond="jacobi", normalize=False))
    assert h0[-1] < cfg.tol and h1[-1] < cfg.tol
    np.testing.assert_allclose(np.asarray(u0), np.asarray(u1), atol=2e-3)


def test_unknown_backend_and_pallas_guard():
    cfg = CFDConfig(n=8)
    with pytest.raises(KeyError, match="unknown backend"):
        solve_steady(cfg, SolverOptions(backend="nope"))
    with pytest.raises(NotImplementedError, match="spmd"):
        solve_steady(cfg, SolverOptions(backend="pallas"))


@pytest.mark.slow
def test_cavity_ghia_through_registry_spmd_multidevice(subproc):
    """The acceptance flow: reference vs spmd agreement on a real 2x2
    fabric, and the Ghia centerline structure through the registry path."""
    subproc("""
        import numpy as np, jax.numpy as jnp
        from repro.apps.cfd import (CFDConfig, SolverOptions, centerline_u,
                                    solve_steady, to_staggered)
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)   # 2 x 2 fabric
        cfg = CFDConfig(n=24, reynolds=100.0, outer_iters=250, tol=5e-6)
        ur, vr, pr, hr = solve_steady(cfg, SolverOptions(backend="reference"))
        us, vs, ps, hs = solve_steady(
            cfg, SolverOptions(backend="spmd", precond="jacobi"), mesh)
        assert hr[-1] < 5e-6 and hs[-1] < 5e-6
        assert abs(jnp.abs(ur - us).max()) < 5e-4
        u_stag, _ = to_staggered(us, vs)
        cl = np.asarray(centerline_u(u_stag))
        assert -0.30 < cl.min() < -0.10
        assert 0.25 < cl.argmin() / len(cl) < 0.75
        assert cl[-1] > 0.4
        print('OK')
    """, n_devices=4)


# ---------------------------------------------------------------------------
# Transient + checkpoint/restore
# ---------------------------------------------------------------------------

def _transient_cfgs():
    cfg = CFDConfig(n=12, reynolds=100.0)
    tcfg = TransientConfig(dt=0.05, n_steps=6, outers_per_step=5,
                           checkpoint_every=2)
    return cfg, tcfg


def test_transient_checkpoint_restore_is_bit_deterministic():
    cfg, tcfg = _transient_cfgs()
    (ua, va, pa), _ = run_transient(cfg, tcfg)   # uninterrupted, no ckpt
    with tempfile.TemporaryDirectory() as d:
        (ub, vb, pb), metrics = run_transient(cfg, tcfg, checkpoint_dir=d)
        assert len(metrics) == tcfg.n_steps
        assert any(f.endswith(".npz") for f in os.listdir(d))
    for a, b in ((ua, ub), (va, vb), (pa, pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transient_replays_identically_after_injected_fault():
    cfg, tcfg = _transient_cfgs()
    (ua, va, pa), _ = run_transient(cfg, tcfg)
    armed = {"v": True}

    def hook(step):
        # step 3 is NOT a checkpoint boundary (checkpoints land at 2, 4, 6):
        # the replay re-runs steps 2-3, exercising the metrics dedup too
        if step == 3 and armed["v"]:
            armed["v"] = False
            raise RuntimeError("injected fault")

    with tempfile.TemporaryDirectory() as d:
        (ub, vb, pb), metrics = run_transient(cfg, tcfg, checkpoint_dir=d,
                                              failure_hook=hook)
    assert not armed["v"], "fault was never injected"
    assert [m["step"] for m in metrics] == list(range(tcfg.n_steps))
    for a, b in ((ua, ub), (va, vb), (pa, pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_channel_scenario_conserves_mass_and_develops():
    cfg = CFDConfig(n=12, reynolds=50.0, scenario="channel", u_in=1.0)
    tcfg = TransientConfig(dt=0.05, n_steps=5, outers_per_step=8)
    (u, v, p), metrics = run_transient(cfg, tcfg)
    h = 1.0 / cfg.n
    outflux = float(u[-1, :].sum() * h)
    assert outflux == pytest.approx(cfg.u_in, abs=1e-5)     # mass fixed
    profile = np.asarray(u[-1, :])
    assert profile[cfg.n // 2] > 1.1 * profile[0]           # center > wall
    assert float(jnp.abs(v[:, -1]).max()) == 0.0            # top wall v = 0
    assert metrics[-1]["continuity"] < 1e-3


# ---------------------------------------------------------------------------
# Precision: f64 registration + the clamp-before-cast bugfix
# ---------------------------------------------------------------------------

def test_f64_policy_registered_but_guarded():
    assert "f64" in precision.POLICIES
    assert precision.POLICIES["f64"] is precision.F64
    if jax.config.jax_enable_x64:
        pytest.skip("suite unexpectedly runs with x64 on")
    with pytest.raises(RuntimeError, match="jax_enable_x64"):
        precision.get_policy("f64")
    # the other registry entries are unaffected by the guard
    assert precision.get_policy("f32") is precision.F32
    with pytest.raises(KeyError, match="unknown precision policy"):
        precision.get_policy("f128")


def test_f64_policy_solves_when_x64_enabled(subproc):
    subproc("""
        import jax
        jax.config.update('jax_enable_x64', True)
        import jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        pol = precision.get_policy('f64')
        assert pol is precision.F64
        assert pol.storage == jnp.dtype(jnp.float64)
        cf = stencil.poisson((6, 6, 6), dtype=jnp.float64)
        x_true = jax.random.normal(jax.random.PRNGKey(0), (6, 6, 6), jnp.float64)
        b = stencil.apply_ref(cf, x_true, policy=pol)
        res = bicgstab.solve_ref(cf, b, tol=1e-12, maxiter=300, policy=pol)
        assert res.x.dtype == jnp.float64
        assert bool(res.converged)
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                   rtol=1e-9, atol=1e-9)
        print('OK')
    """, n_devices=1)


def test_momentum_formation_is_f32_and_clamped_before_storage_cast():
    """bf16_mixed regression: the aP clamp and the d = h/aP division run in
    f32 *before* the storage cast, so an extreme-viscosity diagonal can
    never reach the solver flushed to zero (or d blown to inf)."""
    from repro.apps.cfd.driver import _system_coeffs
    from repro.apps.cfd.grid import global_indices
    from repro.apps.cfd.momentum import form_u_system
    from repro.core.halo import FabricAxes, gather_halo

    cfg = CFDConfig(n=8, reynolds=1e30, alpha_u=1.0, policy=precision.MIXED)
    u, v, p = cell_state(cfg)
    fabric = FabricAxes()
    gi, gj = global_indices(cfg.n, u.shape, 0, 0)
    up = gather_halo(u, fabric, 1, corners=True)
    vp = gather_halo(v, fabric, 1, corners=True)
    pp = gather_halo(p, fabric, 1)
    aP, aE, aW, aN, aS, b, du = form_u_system(cfg, up, vp, pp, u, u, gi, gj)
    # formation stays in f32 whatever the policy
    assert aP.dtype == jnp.float32 and du.dtype == jnp.float32
    # aP underflowed to the clamp floor, not zero; d stayed finite
    assert float(aP[1:-1].min()) >= 9e-13   # the floor, up to f32 rounding
    assert np.isfinite(np.asarray(du)).all()
    cf, bs = _system_coeffs(SolverOptions(normalize=False), cfg.policy,
                            (aP, aE, aW, aN, aS), b)
    assert cf.diag.dtype == jnp.bfloat16
    assert float(jnp.abs(cf.diag).min()) > 0.0   # no zero diagonal in storage
    # one full mixed-precision step produces finite fields
    us, vs, ps, res, _aux = simple_step(
        CavityConfig(n=8, policy=precision.MIXED), *to_staggered(u, v), p)
    assert np.isfinite(np.asarray(us)).all()
    assert np.isfinite(np.asarray(ps)).all()


# ---------------------------------------------------------------------------
# Communication scheduling through the application (ISSUE 5)
# ---------------------------------------------------------------------------

def test_solver_options_validate_schedule_and_p_solver():
    cfg = CFDConfig(n=8)
    from repro.apps.cfd.driver import make_step_fn
    with pytest.raises(KeyError, match="unknown comm schedule"):
        make_step_fn(cfg, SolverOptions(schedule="eager"))
    with pytest.raises(KeyError, match="unknown solver"):
        make_step_fn(cfg, SolverOptions(p_solver="gmres"))
    opts = SolverOptions(p_solver="pipelined_bicgstab")
    assert opts.pressure_solver == "pipelined_bicgstab"
    assert SolverOptions().pressure_solver == "bicgstab"


def test_pipelined_pressure_solve_reference_backend():
    """The SIMPLE loop runs with the single-AllReduce pipelined solver on
    the pressure-correction system and still drives continuity down."""
    cfg = CFDConfig(n=12, reynolds=100.0, outer_iters=30, tol=1e-12)
    u, v, p, hist = solve_steady(
        cfg, SolverOptions(backend="reference",
                           p_solver="pipelined_bicgstab"))
    assert hist[-1] < hist[0] * 0.2
    ug, vg, pg, hg = solve_steady(cfg, SolverOptions(backend="reference"))
    np.testing.assert_allclose(np.asarray(u), np.asarray(ug),
                               rtol=1e-3, atol=1e-3)


def test_cfd_schedules_agree_with_pipelined_pressure_solve(subproc):
    """Acceptance: launch/cfd's SIMPLE iteration with a pipelined pressure
    solve on a 2x2 fabric — the first outer iteration matches bitwise end
    to end across schedules, and the runs stay equivalent to tolerance.
    (The apply itself is asserted bit-identical across schedules in
    tests/test_operator_backends.py; *multi-step* bitwise equality of the
    whole fused SIMPLE program is a compiler property, not a semantics one:
    XLA may contract the warm-started inner solves' setup apply differently
    per program variant at 1 ulp, which truncated Krylov chains amplify —
    see apps/cfd/driver._inner_solve.)"""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.apps.cfd import CFDConfig, SolverOptions
        from repro.apps.cfd.driver import make_step_fn
        from repro.apps.cfd.grid import cell_state
        from repro.core.precision import F32
        from repro.launch.mesh import make_mesh_for_devices

        mesh = make_mesh_for_devices(4)     # 2x2 fabric
        cfg = CFDConfig(n=16, reynolds=100.0, policy=F32)
        opts = {s: SolverOptions(backend='spmd', schedule=s,
                                 p_solver='pipelined_bicgstab')
                for s in ('blocking', 'overlap')}
        s0 = cell_state(cfg)

        # 1) first outer iteration end to end: bitwise
        steps = {s: make_step_fn(cfg, o, mesh) for s, o in opts.items()}
        first = {s: steps[s](*s0, s0[0], s0[1]) for s in steps}
        for fa, fb in zip(first['blocking'][:3], first['overlap'][:3]):
            assert np.array_equal(np.asarray(fa), np.asarray(fb))

        # 2) several outer iterations: equivalent to tolerance, both
        # converging (continuity decreasing)
        state = {s: s0 for s in steps}
        hist = {s: [] for s in steps}
        for _ in range(6):
            for s in steps:
                u, v, p, res, _m = steps[s](*state[s], state[s][0],
                                            state[s][1])
                state[s] = (u, v, p)
                hist[s].append(float(res))
        for fa, fb in zip(state['blocking'], state['overlap']):
            np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                                       rtol=5e-3, atol=5e-3)
        assert hist['overlap'][-1] < hist['overlap'][0]
        print('OK')
    """, n_devices=4)
