"""SIMPLE CFD driver tests (paper §VI Alg. 2): lid-driven cavity."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simple_cfd import CavityConfig, centerline_u, solve_cavity


@pytest.fixture(scope="module")
def cavity():
    cfg = CavityConfig(n=24, reynolds=100.0, outer_iters=250, tol=5e-6)
    u, v, p, hist = solve_cavity(cfg)
    return cfg, u, v, p, hist


def test_simple_converges(cavity):
    cfg, u, v, p, hist = cavity
    assert hist[-1] < 5e-6
    assert hist[-1] < hist[0] / 100


def test_velocity_field_is_divergence_free(cavity):
    cfg, u, v, p, hist = cavity
    h = 1.0 / cfg.n
    div = (u[1:, :] - u[:-1, :] + v[:, 1:] - v[:, :-1]) * h
    assert float(jnp.abs(div).max()) < 1e-4


def test_cavity_recirculation_matches_ghia_qualitatively(cavity):
    """Ghia et al. (1982), Re=100: centerline u_min ~ -0.21 near mid-height.
    First-order upwind on a 24-cell grid is diffusive; accept the known
    coarse-grid band and the correct flow structure."""
    cfg, u, v, p, hist = cavity
    cl = np.asarray(centerline_u(u))
    assert -0.30 < cl.min() < -0.10          # return flow strength
    assert 0.25 < cl.argmin() / len(cl) < 0.75   # near mid-height
    assert cl[-1] > 0.4                      # lid-adjacent cells dragged along
    assert abs(cl[0]) < 0.1                  # near-stationary bottom


def test_no_slip_walls(cavity):
    cfg, u, v, p, hist = cavity
    # boundary faces pinned at zero
    assert float(jnp.abs(u[0, :]).max()) == 0.0
    assert float(jnp.abs(u[-1, :]).max()) == 0.0
    assert float(jnp.abs(v[:, 0]).max()) == 0.0
    assert float(jnp.abs(v[:, -1]).max()) == 0.0


def test_stokes_flow_symmetry():
    """At Re->0 the cavity flow is left-right antisymmetric in u."""
    cfg = CavityConfig(n=16, reynolds=0.5, outer_iters=150, tol=1e-6)
    u, v, p, hist = solve_cavity(cfg)
    un = np.asarray(u)
    np.testing.assert_allclose(un, un[::-1, :], atol=2e-3)
