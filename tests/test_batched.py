"""Batched (many-RHS) solver-stack tests: the block-Krylov batch axis.

The contract under test, layer by layer:

* ``B=1`` batched is **bitwise identical** to the unbatched path (the
  acceptance bar — same ops, broadcast leading axis of extent 1), for
  every backend and both comm schedules.
* Per-RHS solves in a batch behave independently: exact per-RHS
  iteration counts, independent convergence/breakdown masks, and a
  converged RHS freezes at its exit state while the rest keep iterating.
* The fused batched reductions produce per-RHS scalars bitwise equal to
  running each RHS through the unbatched kernels.
* The collective schedule is batch-invariant: one body AllReduce per
  pipelined iteration whether B is 1 or 4 (HLO-asserted, slow tier).

A note on B>1 vs per-RHS-solo comparisons: the *eager* batched step is
bitwise per-RHS (``local_partial`` unrolls per-RHS dots in unbatched
accumulation order), and the generic loops stay bitwise through
``lax.while_loop`` too.  The pipelined BiCGStab body, with its 12
shared-operand dots, gets fused differently by XLA for the (B, ...) vs
(...) graphs (FMA/fusion rounding), so its B>1 trajectory is asserted
allclose rather than bitwise — B=1 vs unbatched stays exact.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bicgstab, precision, stencil
from repro.core.halo import FabricAxes
from repro.core.solvers.common import convergence_test

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SHAPE = (8, 8, 6)


def _run_snippet(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr
    return out.stdout


def _problem(B=None, seed=1, shape=SHAPE):
    cf = stencil.poisson(shape)
    xshape = shape if B is None else (B,) + shape
    x_true = jax.random.normal(jax.random.PRNGKey(seed), xshape, jnp.float32)
    return cf, stencil.rhs_for_solution(cf, x_true), x_true


# ---------------------------------------------------------------------------
# Layer 1: the reference apply
# ---------------------------------------------------------------------------

def test_apply_ref_batched_bitwise():
    cf, b, _ = _problem(B=3)
    u = stencil.apply_ref(cf, b)
    assert u.shape == b.shape
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(u[i]),
                                      np.asarray(stencil.apply_ref(cf, b[i])))


def test_local_apply_batched_matches_ref():
    """The halo layer's padded apply on a degenerate fabric, batched."""
    from repro.core.halo import local_apply

    cf, b, _ = _problem(B=2)
    u = local_apply(cf, b, FabricAxes())
    np.testing.assert_array_equal(np.asarray(u),
                                  np.asarray(stencil.apply_ref(cf, b)))


# ---------------------------------------------------------------------------
# Layer 5: solver semantics (reference backend, eager)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("solver", ["bicgstab", "cg", "pipelined_cg",
                                    "pipelined_bicgstab"])
def test_b1_batched_bitwise_identical_to_unbatched(solver):
    """The acceptance bar: a (1, ...) solve IS the unbatched solve."""
    cf, b, _ = _problem()
    kw = dict(tol=1e-5, maxiter=60, policy=precision.F32, solver=solver)
    ru = bicgstab.solve_ref(cf, b, **kw)
    rb = bicgstab.solve_ref(cf, b[None], **kw)
    assert rb.x.shape == (1,) + SHAPE
    np.testing.assert_array_equal(np.asarray(rb.x[0]), np.asarray(ru.x))
    assert int(rb.iterations[0]) == int(ru.iterations)
    assert bool(rb.converged[0]) == bool(ru.converged)
    np.testing.assert_array_equal(np.asarray(rb.rel_residual[0]),
                                  np.asarray(ru.rel_residual))


@pytest.mark.parametrize("solver", ["bicgstab", "cg", "pipelined_cg"])
def test_batched_matches_per_rhs_solo_bitwise(solver):
    """Each RHS of a B=3 block solve reproduces its solo solve exactly —
    iterations, x, and residual (the per-RHS freeze keeps a converged RHS
    untouched while the others iterate on)."""
    cf, b, _ = _problem(B=3)
    kw = dict(tol=1e-5, maxiter=80, policy=precision.F32, solver=solver)
    rb = bicgstab.solve_ref(cf, b, **kw)
    for i in range(3):
        ri = bicgstab.solve_ref(cf, b[i], **kw)
        assert int(rb.iterations[i]) == int(ri.iterations)
        np.testing.assert_array_equal(np.asarray(rb.x[i]), np.asarray(ri.x))
        np.testing.assert_array_equal(np.asarray(rb.rel_residual[i]),
                                      np.asarray(ri.rel_residual))
    # RHS are genuinely different problems: counts must not be all equal
    # by construction (guards against an accidental lock-step loop)
    assert rb.iterations.shape == (3,)


def test_pipelined_bicgstab_batched_tracks_solo():
    """B>1 pipelined BiCGStab: XLA fuses the batched while-body with
    different rounding (see module docstring), so solo agreement is
    allclose; iteration counts must still match exactly."""
    cf, b, x_true = _problem(B=2)
    kw = dict(tol=1e-5, maxiter=80, policy=precision.F32,
              solver="pipelined_bicgstab")
    rb = bicgstab.solve_ref(cf, b, **kw)
    for i in range(2):
        ri = bicgstab.solve_ref(cf, b[i], **kw)
        assert int(rb.iterations[i]) == int(ri.iterations)
        np.testing.assert_allclose(np.asarray(rb.x[i]), np.asarray(ri.x),
                                   rtol=1e-4, atol=1e-4)
    assert bool(rb.converged.all())
    np.testing.assert_allclose(np.asarray(rb.x), np.asarray(x_true),
                               rtol=2e-3, atol=2e-3)


def test_converged_rhs_freezes_while_others_iterate():
    """A zero RHS converges at iteration 0 (x stays zero, counter stays 0)
    while the live RHS runs its full solo trajectory next to it."""
    cf, b1, _ = _problem()
    b = jnp.stack([jnp.zeros_like(b1), b1])
    kw = dict(tol=1e-5, maxiter=80, policy=precision.F32, solver="bicgstab")
    rb = bicgstab.solve_ref(cf, b, **kw)
    assert int(rb.iterations[0]) == 0 and bool(rb.converged[0])
    assert not np.any(np.asarray(rb.x[0]))
    ri = bicgstab.solve_ref(cf, b1, **kw)
    assert int(rb.iterations[1]) == int(ri.iterations)
    np.testing.assert_array_equal(np.asarray(rb.x[1]), np.asarray(ri.x))


def test_batched_history_shape_and_freeze():
    cf, b, _ = _problem(B=2)
    maxiter = 30
    rb = bicgstab.solve_ref(cf, b, tol=1e-5, maxiter=maxiter,
                            policy=precision.F32, record_history=True)
    h = np.asarray(rb.history)
    assert h.shape == (maxiter, 2)
    # after an RHS converges its history freezes at the exit residual
    for i in range(2):
        k = int(rb.iterations[i])
        assert np.all(h[k:, i] == h[k, i])


def test_batched_breakdown_mask_is_per_rhs():
    """A singular operator row drives breakdown for the RHS that excites
    it; batched next to a healthy Poisson solve both flags stay honest."""
    cf, b1, _ = _problem()
    kw = dict(tol=1e-12, maxiter=5, policy=precision.F32, solver="bicgstab")
    b = jnp.stack([b1, 2.0 * b1])
    rb = bicgstab.solve_ref(cf, b, **kw)
    assert rb.breakdown.shape == (2,) and rb.converged.shape == (2,)
    assert not bool(rb.breakdown.any())


# ---------------------------------------------------------------------------
# Layer 3/4: the fused-kernel backend, degenerate fabric (tier-1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", ["blocking", "overlap"])
@pytest.mark.parametrize("backend", ["spmd", "pallas"])
def test_b1_bitwise_backends_degenerate_fabric(backend, schedule):
    """B=1 == unbatched for the distributed backends on the 1x1 fabric
    (the full multi-device check is the slow subprocess test below)."""
    cf, b, _ = _problem()
    kw = dict(tol=1e-5, maxiter=40, policy=precision.F32,
              backend=backend, schedule=schedule)
    ru = bicgstab.solve_ref(cf, b, **kw)
    rb = bicgstab.solve_ref(cf, b[None], **kw)
    np.testing.assert_array_equal(np.asarray(rb.x[0]), np.asarray(ru.x))
    assert int(rb.iterations[0]) == int(ru.iterations)


def test_pallas_backend_batched_matches_solo():
    cf, b, _ = _problem(B=2)
    kw = dict(tol=1e-5, maxiter=40, policy=precision.F32, backend="pallas")
    rb = bicgstab.solve_ref(cf, b, **kw)
    for i in range(2):
        ri = bicgstab.solve_ref(cf, b[i], **kw)
        assert int(rb.iterations[i]) == int(ri.iterations)
        np.testing.assert_array_equal(np.asarray(rb.x[i]), np.asarray(ri.x))


def test_fused_iter_batched_ops_bitwise():
    """Every fused_iter wrapper: batched rows == per-RHS unbatched rows,
    vectors and scalar partials alike."""
    from repro.kernels.fused_iter.ops import (
        dot_mixed, update_p, update_q_dots, update_xr_dots)

    B, n = 3, int(np.prod(SHAPE))
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r, s, y, x, p, r0 = [jax.random.normal(k, (B,) + SHAPE, jnp.float32)
                         for k in ks]
    alpha = jnp.linspace(0.5, 1.5, B)
    omega = jnp.linspace(0.2, 0.8, B)
    beta = jnp.linspace(-0.3, 0.4, B)

    qb, qyb, yyb = update_q_dots(alpha, r, s, y, interpret=True, batched=True)
    xb, rb, r0rb, rrb = update_xr_dots(alpha, omega, x, p, qb, y, r0,
                                       interpret=True, batched=True)
    pb = update_p(beta, omega, rb, p, s, interpret=True, batched=True)
    db = dot_mixed(r, s, interpret=True, batched=True)
    for i in range(B):
        qi, qyi, yyi = update_q_dots(alpha[i], r[i], s[i], y[i],
                                     interpret=True)
        xi, ri, r0ri, rri = update_xr_dots(alpha[i], omega[i], x[i], p[i],
                                           qi, y[i], r0[i], interpret=True)
        pi = update_p(beta[i], omega[i], ri, p[i], s[i], interpret=True)
        di = dot_mixed(r[i], s[i], interpret=True)
        for got, want in ((qb[i], qi), (qyb[i], qyi), (yyb[i], yyi),
                          (xb[i], xi), (rb[i], ri), (r0rb[i], r0ri),
                          (rrb[i], rri), (pb[i], pi), (db[i], di)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stencil_nd_pallas_batched_bitwise():
    from repro.kernels.stencil_nd.ops import stencil_apply

    spec = stencil.STAR7
    cf, b, _ = _problem(B=2)
    ub = stencil_apply(cf, b, spec=spec, interpret=True)
    for i in range(2):
        np.testing.assert_array_equal(
            np.asarray(ub[i]),
            np.asarray(stencil_apply(cf, b[i], spec=spec, interpret=True)))


# ---------------------------------------------------------------------------
# Satellites: f64 tolerance regression, deprecation shim
# ---------------------------------------------------------------------------

def test_convergence_test_threshold_dtype():
    """In-process half of the f64 regression: the threshold must be formed
    in bnorm2's dtype, not hard-cast to f32 (satellite bugfix)."""
    conv = convergence_test(1e-3, jnp.float32(4.0))
    assert bool(conv(jnp.float32(3.9e-6)))
    assert not bool(conv(jnp.float32(4.1e-6)))


def test_convergence_test_f64_tiny_tol_subprocess():
    """Under x64, a tolerance far below f32 eps must survive squaring —
    the old f32 hard-cast flushed ``tol*tol`` to 0 and never converged."""
    code = (
        "import jax; jax.config.update('jax_enable_x64', True)\n"
        "import jax.numpy as jnp\n"
        "from repro.core.solvers.common import convergence_test\n"
        "conv = convergence_test(1e-25, jnp.float64(1.0))\n"
        "assert bool(conv(jnp.float64(1e-51))), 'tiny f64 tol flushed'\n"
        "assert not bool(conv(jnp.float64(1e-49)))\n"
        "print('OK')\n"
    )
    assert "OK" in _run_snippet(code)


def test_stencil7_deprecation_warning_fires_once():
    """The shim import warns exactly once per process and keeps the legacy
    names importable."""
    code = (
        "import warnings\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    import repro.kernels.stencil7 as s7\n"
        "    import repro.kernels.stencil7  # second import: cached, no warn\n"
        "hits = [x for x in w if issubclass(x.category, DeprecationWarning)\n"
        "        and 'stencil_nd' in str(x.message)]\n"
        "assert len(hits) == 1, [str(x.message) for x in w]\n"
        "assert callable(s7.stencil7_apply) and callable(s7.stencil7_dot)\n"
        "print('OK')\n"
    )
    assert "OK" in _run_snippet(code)


# ---------------------------------------------------------------------------
# Multi-device: B=1 bitwise + batch-invariant collectives (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_distributed_b1_bitwise_all_backends(subproc):
    """Acceptance: B=1 batched == unbatched on a real 2x4 fabric for both
    distributed backends x both schedules, and B=4 matches the reference
    solve (the reference backend's B=1 identity is tier-1, in-process)."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        shape = (8, 8, 6)
        cf = stencil.poisson(shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        kw = dict(tol=1e-5, maxiter=40, policy=precision.F32)
        for backend in ("spmd", "pallas"):
            for schedule in ("blocking", "overlap"):
                ru = bicgstab.solve_distributed(mesh, cf, b, backend=backend,
                                                schedule=schedule, **kw)
                rb = bicgstab.solve_distributed(mesh, cf, b[None],
                                                backend=backend,
                                                schedule=schedule, **kw)
                np.testing.assert_array_equal(np.asarray(rb.x[0]),
                                              np.asarray(ru.x))
                assert int(rb.iterations[0]) == int(ru.iterations), (
                    backend, schedule)
        # a real block solve converges to the manufactured solutions
        xt4 = jax.random.normal(jax.random.PRNGKey(2), (4,) + shape,
                                jnp.float32)
        b4 = stencil.rhs_for_solution(cf, xt4)
        r4 = bicgstab.solve_distributed(mesh, cf, b4, tol=1e-7, maxiter=200,
                                        policy=precision.F32)
        assert bool(r4.converged.all())
        np.testing.assert_allclose(np.asarray(r4.x), np.asarray(xt4),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """)


@pytest.mark.slow
def test_batched_collective_count_is_batch_invariant(subproc):
    """Acceptance: a jitted B=4 pipelined_bicgstab solve lowers to exactly
    1 body AllReduce per iteration — the same totals as B=1 — and the
    ppermute count does not grow with B either."""
    subproc("""
        import jax, jax.numpy as jnp
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        shape = (8, 8, 8)
        cf = stencil.poisson(shape)
        for solver, per_iter_want in (("pipelined_bicgstab", 1),
                                      ("bicgstab", 3)):
            counts = {}
            for B in (1, 4):
                b = jnp.ones((B,) + shape, jnp.float32)
                f = lambda c, bb: bicgstab.solve_distributed(
                    mesh, c, bb, tol=0.0, maxiter=8, policy=precision.F32,
                    solver=solver, schedule="overlap")
                text = jax.jit(f).lower(cf, b).as_text()
                counts[B] = (
                    text.count('all_reduce') + text.count('all-reduce'),
                    text.count('collective_permute')
                    + text.count('collective-permute'))
            assert counts[1] == counts[4], (solver, counts)
            # setup folds into one AllReduce; the loop body is emitted once
            assert counts[1][0] - 1 == per_iter_want, (solver, counts)
        print('OK')
    """, n_devices=4)
