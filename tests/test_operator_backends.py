"""Operator-layer tests: the three backends are interchangeable, the
Pallas-fused backend keeps the 3-AllReduce schedule end to end, and the
comm-scheduling layer (blocking vs overlap halo exchange) is bit-identical
with an unchanged collective count."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision, stencil
from repro.core.comm import SCHEDULES, get_schedule
from repro.core.operator import BACKENDS, make_operator


def _problem(shape, seed=0, spec=None):
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(seed), shape, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), shape, jnp.float32)
    return cf, v


def test_registry_contents():
    assert set(BACKENDS) == {"reference", "spmd", "pallas"}
    with pytest.raises(KeyError, match="unknown backend"):
        make_operator("cuda", stencil.poisson((4, 4, 4)))


def test_schedule_registry_and_operator_carry():
    assert set(SCHEDULES) == {"blocking", "overlap"}
    assert get_schedule(None).name == "overlap"        # default
    assert get_schedule(False).name == "blocking"      # legacy bool spelling
    assert get_schedule(True).name == "overlap"
    with pytest.raises(KeyError, match="unknown comm schedule"):
        get_schedule("eager")
    cf = stencil.poisson((4, 4, 4))
    for backend in sorted(BACKENDS):
        op = make_operator(backend, cf, schedule="blocking")
        assert op.schedule.name == "blocking", backend


@pytest.mark.parametrize("backend", ["reference", "spmd", "pallas"])
def test_backend_apply_matches_oracle(backend):
    """On a 1x1 fabric every backend is the same operator."""
    cf, v = _problem((8, 8, 8))
    u_ref = stencil.apply_ref(cf, v)
    op = make_operator(backend, cf, policy=precision.F32)
    np.testing.assert_allclose(np.asarray(op.apply(v)), np.asarray(u_ref),
                               rtol=1e-5, atol=1e-5)
    d = op.dots([(v, v), (v, u_ref)], precision.F32)
    np.testing.assert_allclose(np.asarray(d[0]), float(jnp.vdot(v, v)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(d[1]), float(jnp.vdot(v, u_ref)),
                               rtol=1e-4, atol=1e-4)


def test_pallas_backend_raw_diag_correction():
    """The fused kernel keeps its unit-diagonal contract; the operator adds
    the raw diagonal's deviation outside the kernel."""
    cf = stencil.heterogeneous_poisson(jax.random.PRNGKey(2), (6, 6, 8))
    v = jax.random.normal(jax.random.PRNGKey(3), (6, 6, 8), jnp.float32)
    u_ref = stencil.apply_ref(cf, v)
    op = make_operator("pallas", cf, policy=precision.F32)
    np.testing.assert_allclose(np.asarray(op.apply(v)), np.asarray(u_ref),
                               rtol=1e-5, atol=1e-5)


def test_overlap_apply_bit_identical_and_same_ppermutes(subproc):
    """Acceptance (ISSUE 5): on a 2x2 fabric the overlap schedule's apply is
    bit-identical to blocking for both distributed backends across the
    stencil family, and lowers to exactly the same collective-permute count
    — overlap changes *when* halos move, never how many messages."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import shard_map
        from repro.core import precision, stencil
        from repro.core.halo import FabricAxes, global_apply
        from repro.core.operator import make_operator
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)      # 2x2 fabric
        fabric = FabricAxes.from_mesh(mesh)
        pspec = fabric.spec(3)
        for name in ('star7', 'star25', 'box27'):
            spec = stencil.get_spec(name)
            shape = (16, 16, 6) if name == 'star25' else (8, 8, 6)
            cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape,
                                             spec=spec)
            v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
            u_ref = stencil.apply_ref(cf, v)
            # spmd: bitwise + collective-permute parity from lowered HLO
            outs, pp = {}, {}
            for schedule in ('blocking', 'overlap'):
                f = jax.jit(lambda c, vv, s=schedule: global_apply(
                    mesh, c, vv, schedule=s))
                outs[schedule] = np.asarray(f(cf, v))
                text = f.lower(cf, v).as_text()
                pp[schedule] = (text.count('collective_permute')
                                + text.count('collective-permute'))
            assert np.array_equal(outs['blocking'], outs['overlap']), name
            assert pp['blocking'] == pp['overlap'] > 0, (name, pp)
            np.testing.assert_allclose(outs['overlap'], np.asarray(u_ref),
                                       rtol=1e-5, atol=1e-5, err_msg=name)
            # pallas: interior through the fused kernel, ring re-run through
            # the same kernel on the exchanged slabs — still bitwise
            pouts = {}
            for schedule in ('blocking', 'overlap'):
                def f(c, vv, s=schedule):
                    op = make_operator('pallas', c, fabric,
                                       policy=precision.F32, schedule=s)
                    return op.apply(vv)
                pouts[schedule] = np.asarray(shard_map(
                    f, mesh=mesh, in_specs=(pspec, pspec), out_specs=pspec,
                    check_vma=False)(cf, v))
            assert np.array_equal(pouts['blocking'], pouts['overlap']), name
            np.testing.assert_allclose(pouts['overlap'], np.asarray(u_ref),
                                       rtol=1e-5, atol=1e-5, err_msg=name)
        print('OK')
    """, n_devices=4)


def test_overlap_solve_bit_identical(subproc):
    """Whole distributed solves are bit-identical across halo schedules
    (mixed precision included)."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        shape = (8, 8, 6)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        b = stencil.rhs_for_solution(
            cf, jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32))
        for policy, bb in ((precision.F32, b),
                           (precision.MIXED, b.astype(jnp.bfloat16))):
            xs = {}
            for schedule in ('blocking', 'overlap'):
                res = bicgstab.solve_distributed(
                    mesh, cf, bb, tol=1e-6, maxiter=40, policy=policy,
                    schedule=schedule)
                xs[schedule] = np.asarray(res.x, np.float32)
            assert np.array_equal(xs['blocking'], xs['overlap']), policy.name
        print('OK')
    """, n_devices=4)


@pytest.mark.slow
def test_distributed_pallas_matches_spmd_trajectory(subproc):
    """Acceptance: the Pallas-fused distributed backend reproduces the SPMD
    backend's residual trajectory to policy tolerance (f32 tight, bf16
    loose), and converges to the manufactured solution."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        shape = (8, 8, 6)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        # (policy, trajectory rtol, iterations compared): bf16's nonlinear
        # rounding feedback decorrelates long trajectories, so the mixed
        # policy is held to a loose tolerance over the early iterations
        for policy, traj_tol, depth in ((precision.F32, 1e-4, 40),
                                        (precision.MIXED, 0.15, 6)):
            bs = b.astype(policy.storage)
            runs = {}
            for backend in ("spmd", "pallas"):
                runs[backend] = bicgstab.solve_distributed(
                    mesh, cf, bs, tol=1e-5, maxiter=40, policy=policy,
                    backend=backend, record_history=True)
            h_spmd = np.asarray(runs["spmd"].history)
            h_pal = np.asarray(runs["pallas"].history)
            n = min(int(runs["spmd"].iterations), int(runs["pallas"].iterations),
                    depth)
            assert n > 0
            np.testing.assert_allclose(h_pal[:n], h_spmd[:n],
                                       rtol=traj_tol, atol=traj_tol)
        res = bicgstab.solve_distributed(mesh, cf, b, tol=1e-8, maxiter=300,
                                         policy=precision.F32, backend="pallas")
        assert bool(res.converged) and not bool(res.breakdown)
        np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                   rtol=2e-4, atol=2e-4)
        print('OK')
    """)


def test_fused_backend_allreduce_count_is_3(subproc):
    """Acceptance: one fused-backend iteration lowers to exactly 3 AllReduces
    (and the same 8 collective-permutes as the SPMD halo path)."""
    subproc("""
        import jax, jax.numpy as jnp
        from repro.core import bicgstab, precision, stencil
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(4)
        shape = (8, 8, 8)
        cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape)
        structs = [jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), cf)]
        f32 = jax.ShapeDtypeStruct(shape, jnp.float32)
        structs += [f32, f32, f32, f32, jax.ShapeDtypeStruct((), jnp.float32)]
        for backend in ("spmd", "pallas"):
            it = bicgstab.make_iteration_fn(mesh, policy=precision.F32,
                                            backend=backend,
                                            fused_reductions=True)
            text = jax.jit(it).lower(*structs).as_text()
            n_ar = text.count("all_reduce") + text.count("all-reduce")
            n_pp = text.count("collective_permute") + text.count("collective-permute")
            assert n_ar == 3, (backend, n_ar)
            assert n_pp == 8, (backend, n_pp)
        print('OK')
    """, n_devices=4)
