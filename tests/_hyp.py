"""Degrade hypothesis-based tests to skips when hypothesis isn't installed.

``pytest.importorskip`` would skip whole modules (most of whose tests don't
need hypothesis), so instead the property tests import ``given``/``settings``/
``st`` from here: with hypothesis present these are the real objects; without
it, ``@given(...)`` replaces the test with a zero-argument stub that calls
``pytest.skip`` at run time (a plain skip marker would leave the strategy
parameters looking like unresolvable fixtures).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """st.* stand-in: any strategy constructor call returns None."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def decorate(f):
            def skipped():
                pytest.skip("hypothesis is not installed (pip install .[test])")
            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped
        return decorate
