"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device.

Multi-device behaviour is tested via subprocess helpers (see
tests/test_distributed.py) so the main process keeps a single CpuDevice.
"""

import os
import subprocess
import sys
import textwrap

import pytest


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N fake XLA host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices


@pytest.fixture(autouse=True)
def _obs_reset():
    """Observability state is process-global; keep tests isolated."""
    yield
    from repro.obs import metrics, trace

    metrics.reset()
    trace.reset()
    trace.disable()
