"""Substrate tests: data determinism, checkpoint atomicity + resharding,
fault-tolerant restart, optimizer behaviour, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMData
from repro.optim.adamw import adamw_init, adamw_update, cosine_lr, global_norm
from repro.optim.compress import compress_grads, decompress_grads
from repro.runtime import FaultTolerantRunner, RunnerConfig


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_in_seed_and_step():
    cfg = DataConfig(vocab=256, seq_len=32, global_batch=4, seed=7)
    d1, d2 = SyntheticLMData(cfg), SyntheticLMData(cfg)
    b1, b2 = d1.batch_at(123), d2.batch_at(123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=256, seq_len=16, global_batch=8, seed=1)
    h0 = SyntheticLMData(cfg, host_index=0, n_hosts=2)
    h1 = SyntheticLMData(cfg, host_index=1, n_hosts=2)
    assert h0.local_batch == h1.local_batch == 4
    assert not np.array_equal(h0.batch_at(5)["tokens"], h1.batch_at(5)["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=256, seq_len=16, global_batch=2)
    b = SyntheticLMData(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_prefetch_iterator_matches_batch_at():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
    data = SyntheticLMData(cfg)
    it = data.iterate(start_step=10)
    for want_step in (10, 11, 12):
        step, batch = next(it)
        assert step == want_step
        np.testing.assert_array_equal(batch["tokens"],
                                      data.batch_at(want_step)["tokens"])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4), jnp.float32),
            "b": {"x": jnp.arange(6, dtype=jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    t = _tree()
    cm.save(100, t)
    restored, step = cm.restore_latest(t)
    assert step == 100
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_gc_keeps_last_k(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree())
    assert cm.all_steps() == [3, 4]


def test_checkpoint_async_then_wait(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(7, _tree(), blocking=False)
    cm.wait()
    assert cm.latest_step() == 7


def test_corrupt_partial_write_is_invisible(tmp_path):
    """A crash mid-write must never surface a loadable-but-bad checkpoint."""
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, _tree())
    # simulate a crash: npz written for step 2 but manifest missing
    import numpy as np_
    np_.savez(os.path.join(str(tmp_path), "step_000000002.npz"), garbage=np_.zeros(3))
    assert cm.latest_step() == 1          # manifest-gated
    restored, step = cm.restore_latest(_tree())
    assert step == 1


def test_checkpoint_reshard_on_load(subproc):
    """Save on 8-device mesh, restore onto 4-device (elastic restart)."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np, os, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh_for_devices
        d = tempfile.mkdtemp()
        mesh8 = make_mesh_for_devices(8)
        sh8 = NamedSharding(mesh8, P("data", "model"))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh8)
        cm = CheckpointManager(d)
        cm.save(5, {"x": x})
        # restore onto a DIFFERENT layout: 4 of the 8 devices, model-only mesh
        from repro.compat import make_mesh
        mesh4 = make_mesh((4,), ("model",), devices=jax.devices()[:4])
        like = jax.ShapeDtypeStruct((8, 8), jnp.float32,
                                    sharding=NamedSharding(mesh4, P("model", None)))
        (restored, step) = cm.restore(5, {"x": like})
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["x"]),
                                      np.arange(64.0).reshape(8, 8))
        assert restored["x"].sharding.num_devices == 4
        print("OK")
    """)


# ---------------------------------------------------------------------------
# Fault-tolerant runner
# ---------------------------------------------------------------------------

def _toy_setup(tmp_path, total_steps=12, ckpt_every=4):
    # 1-param "model": learn the mean of token values (decreasing loss)
    def train_step(params, opt, batch):
        def loss_fn(p):
            x = batch["tokens"].astype(jnp.float32) / 256.0
            return jnp.mean((x - p["mu"]) ** 2), jnp.float32(0.0)

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, lr=0.05, weight_decay=0.0)
        return params, opt, {"loss": loss}

    data = SyntheticLMData(DataConfig(vocab=256, seq_len=16, global_batch=2))
    params = {"mu": jnp.zeros((), jnp.float32)}
    opt = adamw_init(params)
    ckpt = CheckpointManager(str(tmp_path))
    cfg = RunnerConfig(total_steps=total_steps, checkpoint_every=ckpt_every,
                       async_checkpoint=False)
    return cfg, train_step, data, ckpt, params, opt


def test_runner_completes_and_checkpoints(tmp_path):
    cfg, step, data, ckpt, params, opt = _toy_setup(tmp_path)
    runner = FaultTolerantRunner(cfg, train_step=jax.jit(step), data=data, ckpt=ckpt)
    p, o = runner.run(params, opt)
    assert ckpt.latest_step() == cfg.total_steps
    losses = [m["loss"] for m in runner.metrics_history]
    assert losses[-1] < losses[0]


def test_runner_survives_injected_failures(tmp_path):
    cfg, step, data, ckpt, params, opt = _toy_setup(tmp_path, total_steps=16,
                                                    ckpt_every=4)
    boom = {"armed": True}

    def failure_hook(s):
        if s == 9 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected preemption at step 9")

    runner = FaultTolerantRunner(cfg, train_step=jax.jit(step), data=data,
                                 ckpt=ckpt, failure_hook=failure_hook)
    runner.run(params, opt)
    assert runner.restarts == 1
    # replay determinism: the metrics after restart re-cover steps 8..9
    steps = [m["step"] for m in runner.metrics_history]
    assert steps.count(8) == 2            # step 8 replayed from the step-8 ckpt
    first = [m["loss"] for m in runner.metrics_history if m["step"] == 8]
    assert abs(first[0] - first[1]) < 1e-6  # bit-deterministic replay


def test_runner_exhausts_restart_budget(tmp_path):
    cfg, step, data, ckpt, params, opt = _toy_setup(tmp_path, total_steps=8)
    cfg.max_restarts = 2

    def always_fail(s):
        if s == 3:
            raise RuntimeError("persistent fault")

    runner = FaultTolerantRunner(cfg, train_step=jax.jit(step), data=data,
                                 ckpt=ckpt, failure_hook=always_fail)
    with pytest.raises(RuntimeError, match="restart budget"):
        runner.run(params, opt)


# ---------------------------------------------------------------------------
# Optimizer + compression
# ---------------------------------------------------------------------------

def test_cosine_schedule_shape():
    # step 0 takes a real (1/warmup) step — a silent-no-op first step was a bug
    assert abs(float(cosine_lr(jnp.int32(0), peak=1.0, warmup=10, total=100)) - 0.1) < 1e-6
    assert abs(float(cosine_lr(jnp.int32(10), peak=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100, floor=0.1))
    assert abs(end - 0.1) < 1e-6


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    for i in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(params, grads, opt, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e6)}
    p2, _ = adamw_update(params, grads, opt, lr=0.1, clip_norm=1.0, weight_decay=0.0)
    assert float(jnp.abs(p2["w"]).max()) < 1.0


def test_compression_error_feedback_preserves_convergence():
    """int8-compressed gradients with error feedback still drive a quadratic
    to its minimum (the 1000-node DP-traffic trick, tested for correctness)."""
    params = {"w": jnp.array([3.0, -2.0, 1.5, -0.5])}
    opt = adamw_init(params)
    err = None
    for i in range(400):
        grads = {"w": 2 * params["w"]}
        q, scales, err = compress_grads(grads, err)
        grads_hat = decompress_grads(q, scales)
        params, opt = adamw_update(params, grads_hat, opt, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 2e-2


def test_compression_is_4x_smaller():
    g = {"w": jnp.ones((1024,), jnp.float32)}
    q, s, e = compress_grads(g, None)
    assert q["w"].dtype == jnp.int8
    assert q["w"].nbytes == g["w"].nbytes // 4


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4, 3))}
    np.testing.assert_allclose(float(global_norm(t)), np.sqrt(15.0), rtol=1e-6)
