"""Preconditioner tests: Chebyshev cuts Poisson iterations >=30%, Jacobi
does real work on raw variable-diagonal operators, and everything still
converges to the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bicgstab, precision, stencil
from repro.core.operator import make_operator
from repro.core.precond import (
    PrecondConfig, build_precond, gershgorin_bounds, get_precond_config,
)


def test_config_validation():
    with pytest.raises(ValueError, match="unknown preconditioner"):
        PrecondConfig(name="ilu")
    with pytest.raises(ValueError, match="degree"):
        PrecondConfig(name="chebyshev", degree=0)
    assert get_precond_config(None).name == "none"
    assert get_precond_config("jacobi").name == "jacobi"
    cfg = get_precond_config(PrecondConfig(name="chebyshev"), degree=5)
    assert cfg.degree == 5


def test_gershgorin_bounds_enclose_spectrum():
    cf = stencil.poisson((5, 5, 5))
    lo, hi = gershgorin_bounds(cf)
    w = np.linalg.eigvalsh(stencil.to_dense(cf))
    assert float(lo) <= w.min() + 1e-6
    assert float(hi) >= w.max() - 1e-6


def test_chebyshev_approximates_inverse():
    """Higher degree => M^-1 v closer to A^-1 v (on the bounded spectrum)."""
    cf = stencil.poisson((5, 5, 5))
    v = jax.random.normal(jax.random.PRNGKey(0), (5, 5, 5), jnp.float32)
    A = stencil.to_dense(cf)
    z_true = np.linalg.solve(A, np.asarray(v, np.float64).ravel())
    op = make_operator("reference", cf, policy=precision.F32)
    errs = []
    for degree in (1, 3, 6):
        M = build_precond(
            PrecondConfig(name="chebyshev", degree=degree, lmin_floor=0.01), op)
        z = np.asarray(M.apply(v), np.float64).ravel()
        errs.append(np.linalg.norm(z - z_true) / np.linalg.norm(z_true))
    assert errs[2] < errs[1] < errs[0]


def test_chebyshev_cuts_poisson_iterations_30pct():
    """The acceptance lever at test scale (the 48x48x32 headline run lives
    in benchmarks/solver_matrix.py): right-Chebyshev BiCGStab on Poisson
    star7 in >=30% fewer iterations, same solution."""
    shape = (24, 24, 16)
    cf = stencil.poisson(shape)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    base = bicgstab.solve_ref(cf, b, tol=1e-6, maxiter=500)
    cheb = bicgstab.solve_ref(cf, b, tol=1e-6, maxiter=500,
                              precond=PrecondConfig(name="chebyshev", degree=3))
    assert bool(base.converged) and bool(cheb.converged)
    assert int(cheb.iterations) <= 0.7 * int(base.iterations), (
        int(base.iterations), int(cheb.iterations))
    np.testing.assert_allclose(np.asarray(cheb.x), np.asarray(x_true),
                               rtol=5e-3, atol=5e-3)


def test_jacobi_identity_on_normalized_family():
    """The paper's operators are pre-normalized: Jacobi must be a no-op."""
    cf = stencil.poisson((6, 6, 6))
    b = stencil.rhs_for_solution(
        cf, jax.random.normal(jax.random.PRNGKey(1), (6, 6, 6), jnp.float32))
    plain = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=200)
    jac = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=200, precond="jacobi")
    assert int(plain.iterations) == int(jac.iterations)
    np.testing.assert_allclose(np.asarray(plain.x), np.asarray(jac.x),
                               rtol=1e-6, atol=1e-6)


def test_raw_heterogeneous_matches_dense_oracle():
    cf = stencil.heterogeneous_poisson(jax.random.PRNGKey(3), (5, 5, 4))
    assert cf.diag is not None
    v = jax.random.normal(jax.random.PRNGKey(4), (5, 5, 4), jnp.float32)
    A = stencil.to_dense(cf)
    u = A @ np.asarray(v, np.float64).ravel()
    np.testing.assert_allclose(np.asarray(stencil.apply_ref(cf, v)).ravel(),
                               u, rtol=1e-4, atol=1e-4)
    unit, diag = cf.normalized()
    assert unit.diag is None
    np.testing.assert_allclose(
        np.asarray(stencil.apply_ref(unit, v)).ravel(),
        (A / np.asarray(diag, np.float64).ravel()[:, None]
         @ np.asarray(v, np.float64).ravel()),
        rtol=1e-4, atol=1e-4)


def test_jacobi_warm_start_is_translated_to_hat_space():
    """A warm start near the solution must help a right-Jacobi solve exactly
    as it helps the unpreconditioned one: wrap_right's hat-space iterate is
    ``x_hat = D x``, so solvers must hand it ``D x0``, not ``x0`` (the
    legacy-path bug that made SIMPLE's truncated inner solves stall)."""
    shape = (10, 10, 8)
    cf = stencil.heterogeneous_poisson(jax.random.PRNGKey(3), shape)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    near = x_true + 1e-4 * jnp.ones_like(x_true)
    cold = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=3000, precond="jacobi")
    warm = bicgstab.solve_ref(cf, b, x0=near, tol=1e-8, maxiter=3000,
                              precond="jacobi")
    assert bool(warm.converged)
    assert int(warm.iterations) < int(cold.iterations), (
        int(cold.iterations), int(warm.iterations))
    np.testing.assert_allclose(np.asarray(warm.x), np.asarray(x_true),
                               rtol=5e-3, atol=5e-3)


def test_jacobi_cuts_heterogeneous_iterations():
    shape = (12, 12, 8)
    cf = stencil.heterogeneous_poisson(jax.random.PRNGKey(3), shape,
                                       contrast=2.0)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    base = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=3000)
    jac = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=3000, precond="jacobi")
    assert bool(base.converged) and bool(jac.converged)
    assert int(jac.iterations) <= 0.7 * int(base.iterations), (
        int(base.iterations), int(jac.iterations))
    np.testing.assert_allclose(np.asarray(jac.x), np.asarray(x_true),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("specname", ["star7", "star25", "box27"])
def test_preconditioned_solve_across_family(specname):
    """Chebyshev-preconditioned BiCGStab agrees with the dense oracle for
    every stencil shape."""
    shape = (6, 8, 6) if specname != "star25" else (8, 9, 8)
    spec = stencil.get_spec(specname)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
    x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    b = stencil.rhs_for_solution(cf, x_true)
    res = bicgstab.solve_ref(cf, b, tol=1e-8, maxiter=500,
                             precond=PrecondConfig(name="chebyshev", degree=2))
    assert bool(res.converged), specname
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.slow
def test_distributed_preconditioned_solve(subproc):
    """Preconditioned BiCGStab inside shard_map (bounds reduced over the
    fabric with pmax) matches the manufactured solution, on both the SPMD
    and Pallas-fused backends."""
    subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import bicgstab, precision, stencil
        from repro.core.precond import PrecondConfig
        from repro.launch.mesh import make_mesh_for_devices
        mesh = make_mesh_for_devices(8)
        shape = (16, 16, 8)
        cf = stencil.poisson(shape)
        x_true = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
        b = stencil.rhs_for_solution(cf, x_true)
        base = bicgstab.solve_distributed(mesh, cf, b, tol=1e-6, maxiter=500,
                                          policy=precision.F32)
        for backend in ("spmd", "pallas"):
            res = bicgstab.solve_distributed(
                mesh, cf, b, tol=1e-6, maxiter=500, policy=precision.F32,
                backend=backend,
                precond=PrecondConfig(name="chebyshev", degree=3))
            assert bool(res.converged), backend
            assert int(res.iterations) < int(base.iterations), backend
            np.testing.assert_allclose(np.asarray(res.x), np.asarray(x_true),
                                       rtol=5e-3, atol=5e-3)
        print('OK')
    """)
