"""Tuning-cache tests: round-trip persistence, key stability, fallback
semantics, tile-divisibility clamping, and the fused boundary-ring
epilogue's bitwise identity + launch accounting."""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, stencil, tuning
from repro.core.halo import FabricAxes
from repro.kernels.stencil_nd.fused import fused_ring_apply
from repro.kernels.stencil_nd.kernel import traced_call_count
from repro.kernels.stencil_nd.ops import ring_patch_apply, tile_apply


def _cell(specname, dtype, shape, seed=0):
    spec = stencil.get_spec(specname)
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(seed), shape,
                                     dtype=dtype, spec=spec)
    v = jax.random.normal(jax.random.PRNGKey(seed + 1), shape,
                          jnp.float32).astype(dtype)
    return spec, [cf.diags[n] for n in spec.names], v


# ---------------------------------------------------------------------------
# Cache mechanics
# ---------------------------------------------------------------------------

def test_cache_key_is_stable():
    # the literal format is the contract: cache files outlive code revisions
    assert tuning.cache_key(stencil.STAR7, jnp.float32,
                            (48, 48, 32)) == "star7/float32/48x48x32"
    assert tuning.cache_key(stencil.get_spec("box27"), jnp.bfloat16,
                            (16, 8, 4)) == "box27/bfloat16/16x8x4"


def test_cache_round_trip(tmp_path):
    path = str(tmp_path / "cache.json")
    cache = tuning.TuningCache(path)
    cfg = tuning.KernelConfig(block=(8, 4), zc=16, resident=True,
                              fuse_ring=True)
    cache.put("star7/float32/16x8x32", cfg, {"best_seconds": 1e-3})
    cache.save()

    loaded = tuning.TuningCache.load(path)
    assert len(loaded) == 1
    assert loaded.get("star7/float32/16x8x32") == cfg
    assert loaded.entries["star7/float32/16x8x32"]["best_seconds"] == 1e-3
    with open(path) as f:
        assert json.load(f)["format"] == "repro.tuning_cache.v1"


def test_cache_load_missing_or_corrupt_is_empty(tmp_path):
    assert len(tuning.TuningCache.load(str(tmp_path / "nope.json"))) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert len(tuning.TuningCache.load(str(bad))) == 0


def test_lookup_defaults_without_cache():
    cfg, src = tuning.lookup_config(stencil.STAR7, jnp.float32, (12, 10, 8),
                                    cache=tuning.TuningCache(None))
    assert src == "default"
    assert cfg == tuning.default_config(stencil.STAR7, jnp.float32,
                                        (12, 10, 8))
    assert cfg.block == (12, 10) and not cfg.fuse_ring  # pre-tuning behavior


def test_lookup_hits_cache_and_rejects_stale():
    cache = tuning.TuningCache(None)
    tuned = tuning.KernelConfig(block=(6, 5), zc=4, fuse_ring=True)
    cache.put(tuning.cache_key(stencil.STAR7, jnp.float32, (12, 10, 8)),
              tuned)
    cfg, src = tuning.lookup_config(stencil.STAR7, jnp.float32, (12, 10, 8),
                                    cache=cache)
    assert (cfg, src) == (tuned, "cache")

    # same entry against a shape its tile no longer divides -> default + warn
    cache.put(tuning.cache_key(stencil.STAR7, jnp.float32, (13, 10, 8)),
              tuned)
    with pytest.warns(UserWarning, match="stale"):
        cfg, src = tuning.lookup_config(stencil.STAR7, jnp.float32,
                                        (13, 10, 8), cache=cache)
    assert src == "stale"
    assert cfg == tuning.default_config(stencil.STAR7, jnp.float32,
                                        (13, 10, 8))


def test_lookup_ignores_batch_dim():
    """A batched (B, bx, by, Z) apply must hit the cell tuned at the mesh
    shape: only the trailing mesh dims key the lookup (the kernel's
    per-step working set is one RHS's tile either way)."""
    cache = tuning.TuningCache(None)
    tuned = tuning.KernelConfig(block=(60, 35), zc=48, fuse_ring=True)
    cache.put(tuning.cache_key(stencil.STAR7, jnp.float32, (600, 595, 96)),
              tuned)
    for shape in ((600, 595, 96), (8, 600, 595, 96), (2, 8, 600, 595, 96)):
        cfg, src = tuning.lookup_config(stencil.STAR7, jnp.float32, shape,
                                        cache=cache)
        assert (cfg, src) == (tuned, "cache"), shape
    # and an untuned batched shape still falls through to default
    _, src = tuning.lookup_config(stencil.STAR7, jnp.float32,
                                  (8, 12, 10, 8), cache=cache)
    assert src == "default"


def test_env_var_disables_lookup(monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_CACHE", "off")
    assert tuning.resolve_cache_path() is None
    assert tuning.get_cache() is None
    _, src = tuning.lookup_config(stencil.STAR7, jnp.float32, (8, 8, 8))
    assert src == "default"


def test_env_var_points_lookup_at_file(tmp_path, monkeypatch):
    path = str(tmp_path / "cache.json")
    cache = tuning.TuningCache(path)
    tuned = tuning.KernelConfig(block=(4, 4), zc=8, fuse_ring=True)
    cache.put(tuning.cache_key(stencil.STAR7, jnp.float32, (8, 8, 8)), tuned)
    cache.save()
    monkeypatch.setenv("REPRO_TUNING_CACHE", path)
    cfg, src = tuning.lookup_config(stencil.STAR7, jnp.float32, (8, 8, 8))
    assert (cfg, src) == (tuned, "cache")


# ---------------------------------------------------------------------------
# Divisibility validation (satellite bugfix)
# ---------------------------------------------------------------------------

def test_nearest_divisor_paper_tiles():
    # the paper's unpadded 600 x 595 local tiles: a 64-ish request must
    # land on real divisors, not crash in pallas_call
    assert tuning.nearest_divisor(600, 64) == 60
    assert tuning.nearest_divisor(595, 64) == 35
    assert tuning.nearest_divisor(7, 64) == 7
    assert tuning.nearest_divisor(13, 4) == 1


def test_validate_config_clamps_and_warns():
    cfg = tuning.KernelConfig(block=(64, 64), zc=64)
    with pytest.warns(UserWarning, match="nearest valid tile"):
        fixed = tuning.validate_config(cfg, (600, 595, 96))
    assert fixed.block == (60, 35) and fixed.zc == 48
    assert fixed.divides((600, 595, 96))
    # an already-valid config passes through untouched, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert tuning.validate_config(fixed, (600, 595, 96)) is fixed


def test_kernel_clamps_bad_tile_at_trace_time():
    """An odd-shaped block with a non-dividing requested tile must fall back
    (with a warning) and still match the untiled result bitwise."""
    spec, cl, v = _cell("star7", jnp.float32, (6, 10, 8))
    vp = jnp.pad(v, spec.radius)
    good = tuning.KernelConfig(block=(6, 10), zc=8)
    bad = tuning.KernelConfig(block=(4, 4), zc=3)   # divides nothing here
    u_ref = tile_apply(vp, cl, spec, good, interpret=True)
    with pytest.warns(UserWarning, match="nearest valid tile"):
        u_bad = tile_apply(vp, cl, spec, bad, interpret=True)
    np.testing.assert_allclose(np.asarray(u_ref), np.asarray(u_bad),
                               rtol=0, atol=0)


@pytest.mark.parametrize("specname", ["star7", "box27"])
def test_xy_tiling_bitwise_equivalence(specname):
    """Any valid (bx, by, zc) tiling is bitwise identical to the full-block
    pass (per-element canonical-order accumulation is tile-independent)."""
    spec, cl, v = _cell(specname, jnp.float32, (8, 12, 16))
    vp = jnp.pad(v, spec.radius)
    base = tile_apply(vp, cl, spec,
                      tuning.KernelConfig(block=(8, 12), zc=16),
                      interpret=True)
    for blk, zc in (((4, 12), 16), ((8, 6), 8), ((4, 4), 4), ((2, 3), 2)):
        u = tile_apply(vp, cl, spec, tuning.KernelConfig(block=blk, zc=zc),
                       interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(u),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Fused boundary-ring epilogue (tentpole)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("specname", ["star7", "star25", "box27"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ring_bitwise_identical_to_split(specname, dtype):
    """The overlap schedule's two forms — interior kernel + per-region ring
    patches vs one fused pass over the exchanged block — must agree
    bitwise, for every spec depth and in reduced precision."""
    shape = (8, 8, 8) if specname == "star25" else (6, 8, 8)
    spec, cl, v = _cell(specname, dtype, shape)
    fabric = FabricAxes(nx=2, ny=2)
    config = tuning.KernelConfig(block=shape[:2], zc=shape[2])
    exchange = tuning.synthetic_exchange(v, spec, fabric)

    u_fused = fused_ring_apply(exchange, cl, spec, config, interpret=True)
    u_int = tile_apply(jnp.pad(v, spec.radius), cl, spec, config,
                       interpret=True)
    u_split = ring_patch_apply(exchange, cl, spec, config, u_int, fabric,
                               interpret=True)
    assert u_fused.dtype == u_split.dtype == v.dtype
    np.testing.assert_allclose(np.asarray(u_fused, np.float32),
                               np.asarray(u_split, np.float32),
                               rtol=0, atol=0)


def test_fused_ring_single_launch_vs_split():
    """Launch accounting: the fused form traces exactly 1 pallas_call; the
    split form 1 (interior) + one per boundary region."""
    spec, cl, v = _cell("star7", jnp.float32, (6, 8, 8))
    fabric = FabricAxes(nx=2, ny=2)
    config = tuning.KernelConfig(block=(6, 8), zc=8)
    exchange = tuning.synthetic_exchange(v, spec, fabric)
    n_regions = len(comm.boundary_regions(v.shape, fabric, spec.radius))
    assert n_regions == 4   # both x faces + both y faces on a 2x2 fabric

    c0 = traced_call_count()
    fused_ring_apply(exchange, cl, spec, config, interpret=True)
    assert traced_call_count() - c0 == 1

    c1 = traced_call_count()
    u = tile_apply(jnp.pad(v, spec.radius), cl, spec, config, interpret=True)
    ring_patch_apply(exchange, cl, spec, config, u, fabric, interpret=True)
    assert traced_call_count() - c1 == 1 + n_regions


def test_operator_fuse_ring_override_matches():
    """pallas_local_apply under the overlap schedule: fuse_ring True/False
    and the cache-resolved default all agree bitwise on a 1x1 fabric."""
    from repro.core.precision import F32
    from repro.kernels.stencil_nd import pallas_local_apply

    shape = (6, 8, 8)
    spec = stencil.STAR7
    cf = stencil.random_nonsymmetric(jax.random.PRNGKey(0), shape, spec=spec)
    cfu = stencil.StencilCoeffs(cf.diags)
    v = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    outs = [pallas_local_apply(cfu, v, FabricAxes(), policy=F32,
                               schedule="overlap", interpret=True,
                               fuse_ring=f) for f in (None, False, True)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# The sweep itself
# ---------------------------------------------------------------------------

def test_autotune_cell_sweeps_then_hits(tmp_path):
    cache = tuning.TuningCache(str(tmp_path / "cache.json"))
    spec = stencil.STAR7
    rec = tuning.autotune_cell(spec, jnp.float32, (8, 8, 8), cache=cache,
                               smoke=True, repeats=1, interpret=True)
    assert not rec["cache_hit"]
    assert rec["n_candidates"] >= 2
    assert rec["speedup_vs_default"] >= 1.0   # default is candidate 0
    assert rec["roofline_frac_tuned"] > 0

    # second call: pure cache hit, identical winner, no re-sweep
    rec2 = tuning.autotune_cell(spec, jnp.float32, (8, 8, 8), cache=cache,
                                smoke=True, repeats=1, interpret=True)
    assert rec2["cache_hit"]
    assert rec2["config"] == rec["config"]

    # and the persisted file serves lookups
    loaded = tuning.TuningCache.load(str(tmp_path / "cache.json"))
    cfg, src = tuning.lookup_config(spec, jnp.float32, (8, 8, 8),
                                    cache=loaded)
    assert src == "cache"
    assert cfg.to_json() == rec["config"]


def test_candidate_configs_default_first_and_valid():
    spec = stencil.get_spec("star25")
    shape = (12, 10, 16)
    cands = tuning.candidate_configs(spec, jnp.float32, shape)
    assert cands[0] == tuning.default_config(spec, jnp.float32, shape)
    assert len(cands) == len(set(cands))      # deduplicated
    assert all(c.divides(shape) for c in cands)
    assert any(c.fuse_ring for c in cands)    # the epilogue axis is swept


def test_synthetic_exchange_layout():
    """Interior == v bitwise; only split-axis halos carry values (the
    invariant the fused-vs-split identity rests on)."""
    spec = stencil.STAR7
    v = jax.random.normal(jax.random.PRNGKey(0), (6, 8, 8), jnp.float32)
    ex = tuning.synthetic_exchange(v, spec, FabricAxes(nx=2, ny=2))
    r = spec.radius
    inner = tuple(slice(r, -r) for _ in range(3))
    np.testing.assert_array_equal(np.asarray(ex.padded[inner]),
                                  np.asarray(v))
    assert np.any(np.asarray(ex.padded[:r, r:-r, r:-r]))    # x halo filled
    # the unsplit z axis: its halo (away from x/y slab corners) stays zero
    assert not np.any(np.asarray(ex.padded[r:-r, r:-r, :r]))
