#!/usr/bin/env python3
"""Compare two observability run bundles and fail on regressions.

    python scripts/compare_runs.py <baseline_run_dir> <candidate_run_dir> \
        [--max-iter-increase-pct 0] [--max-collective-increase 0] \
        [--min-solves-per-sec-ratio 0.8] [--min-roofline-ratio 0.5]

Each run dir is a ``repro.obs.v1`` bundle written by ``--obs`` launches
(``results/runs/<run_id>/`` with ``manifest.json`` + ``events.jsonl``, see
docs/observability.md).  The script diffs the metrics that matter for the
solver stack:

* **iterations** — ``solve.iterations_max`` gauge.  More iterations than
  baseline (beyond ``--max-iter-increase-pct``) is a convergence
  regression.  On by default (0% slack).
* **collectives** — AllReduce / ppermute totals summed from the
  ``collectives`` events (the HLO-counted ground truth emitted at launch).
  Any growth beyond ``--max-collective-increase`` ops is a communication-
  schedule regression.  On by default (0 slack).
* **solves/sec** and **roofline fraction** — throughput gauges.  Timing is
  machine-dependent, so these checks are OFF by default (ratio 0); enable
  with e.g. ``--min-solves-per-sec-ratio 0.8`` when comparing runs from
  the same machine.

Exits 0 when the candidate is no worse than the baseline under the active
thresholds, 1 with a regression list otherwise, 2 on malformed bundles.
Stdlib only — runs anywhere, no repo import needed.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load_run(run_dir: str) -> tuple[dict, list[dict]]:
    man_path = os.path.join(run_dir, "manifest.json")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise SystemExit(f"error: cannot read {man_path}: {e}")
    if manifest.get("schema") != "repro.obs.v1":
        raise SystemExit(f"error: {man_path} is not a repro.obs.v1 manifest "
                         f"(schema={manifest.get('schema')!r})")
    events = []
    ev_path = os.path.join(run_dir, "events.jsonl")
    if os.path.exists(ev_path):
        with open(ev_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    events.append(json.loads(line))
    return manifest, events


def gauge(manifest: dict, name: str):
    return manifest.get("metrics", {}).get("gauges", {}).get(name)


def collective_totals(events: list[dict]) -> dict[str, int]:
    """Sum AllReduce / ppermute totals over every `collectives` event."""
    totals = {"allreduce_total": 0, "ppermute_total": 0}
    seen = False
    for e in events:
        if e.get("event") == "collectives":
            seen = True
            for k in totals:
                totals[k] += int(e.get(k, 0))
    return totals if seen else {}


class Comparison:
    def __init__(self) -> None:
        self.rows: list[tuple[str, str, str, str]] = []
        self.regressions: list[str] = []

    def check(self, name, base, cand, ok, detail="") -> None:
        fmt = lambda v: "-" if v is None else (f"{v:.4g}" if isinstance(v, float) else str(v))
        verdict = "skip" if ok is None else ("ok" if ok else "REGRESSION")
        self.rows.append((name, fmt(base), fmt(cand), verdict))
        if ok is False:
            self.regressions.append(f"{name}: baseline={fmt(base)} "
                                    f"candidate={fmt(cand)} {detail}".rstrip())

    def report(self) -> int:
        w = max(len(r[0]) for r in self.rows) if self.rows else 10
        print(f"{'metric':<{w}}  {'baseline':>12}  {'candidate':>12}  verdict")
        for name, base, cand, verdict in self.rows:
            print(f"{name:<{w}}  {base:>12}  {cand:>12}  {verdict}")
        if self.regressions:
            print(f"\n{len(self.regressions)} regression(s):", file=sys.stderr)
            for r in self.regressions:
                print(f"  - {r}", file=sys.stderr)
            return 1
        print("\nno regressions under the active thresholds")
        return 0


def compare(base_dir: str, cand_dir: str, args) -> int:
    base_man, base_ev = load_run(base_dir)
    cand_man, cand_ev = load_run(cand_dir)
    print(f"baseline : {base_man['run_id']} ({base_man['kind']}, "
          f"git {base_man.get('git', {}).get('sha', '?')[:12]})")
    print(f"candidate: {cand_man['run_id']} ({cand_man['kind']}, "
          f"git {cand_man.get('git', {}).get('sha', '?')[:12]})\n")

    cmp = Comparison()

    # -- convergence: solver iterations --------------------------------
    b, c = gauge(base_man, "solve.iterations_max"), gauge(cand_man, "solve.iterations_max")
    if b is None or c is None:
        cmp.check("solve.iterations_max", b, c, None)
    else:
        limit = b * (1.0 + args.max_iter_increase_pct / 100.0)
        cmp.check("solve.iterations_max", b, c, c <= limit,
                  f"(limit {limit:.4g}, --max-iter-increase-pct "
                  f"{args.max_iter_increase_pct:g})")

    # -- communication: HLO-counted collective totals ------------------
    bt, ct = collective_totals(base_ev), collective_totals(cand_ev)
    for key in ("allreduce_total", "ppermute_total"):
        if not bt or not ct:
            cmp.check(f"collectives.{key}", bt.get(key), ct.get(key), None)
        else:
            cmp.check(f"collectives.{key}", bt[key], ct[key],
                      ct[key] <= bt[key] + args.max_collective_increase,
                      f"(--max-collective-increase {args.max_collective_increase})")

    # -- throughput (opt-in: machine-dependent) ------------------------
    for name, ratio, flag in (
            ("solve.solves_per_sec", args.min_solves_per_sec_ratio,
             "--min-solves-per-sec-ratio"),
            ("roofline.fraction", args.min_roofline_ratio,
             "--min-roofline-ratio")):
        b, c = gauge(base_man, name), gauge(cand_man, name)
        if ratio <= 0 or b is None or c is None:
            cmp.check(name, b, c, None)
        else:
            cmp.check(name, b, c, c >= b * ratio,
                      f"(floor {b * ratio:.4g}, {flag} {ratio:g})")

    return cmp.report()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n", 1)[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("baseline", help="baseline run dir (results/runs/<id>)")
    ap.add_argument("candidate", help="candidate run dir to vet")
    ap.add_argument("--max-iter-increase-pct", type=float, default=0.0,
                    help="allowed %% growth in solve.iterations_max")
    ap.add_argument("--max-collective-increase", type=int, default=0,
                    help="allowed growth in AllReduce/ppermute totals (ops)")
    ap.add_argument("--min-solves-per-sec-ratio", type=float, default=0.0,
                    help="candidate/baseline throughput floor (0 = skip)")
    ap.add_argument("--min-roofline-ratio", type=float, default=0.0,
                    help="candidate/baseline roofline-fraction floor (0 = skip)")
    args = ap.parse_args(argv)
    return compare(args.baseline, args.candidate, args)


if __name__ == "__main__":
    sys.exit(main())
