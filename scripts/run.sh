#!/usr/bin/env bash
# Known-good environment for repro runs and benchmarks, so timings are
# comparable across machines and CI:
#
#   scripts/run.sh -m repro.launch.solve --mesh 48 48 32
#   scripts/run.sh -m benchmarks.kernel_autotune --smoke
#   REPRO_DEVICES=512 scripts/run.sh -m benchmarks.hillclimb --cell stencil
#
# Pins: tcmalloc (when installed) — thread-friendly malloc, matters for the
# interpret-mode Pallas sweeps; quiet TF/XLA logging; a fixed fake-device
# count so shard_map fabrics are reproducible; PYTHONPATH=src.  Set
# REPRO_X64=1 to enable float64 (the f64 policy path); REPRO_DEVICES to
# change the host-platform device count (default 8: the 2x2x2 test fabric).
set -euo pipefail
cd "$(dirname "$0")/.."

for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
  if [[ -e "$so" ]]; then
    export LD_PRELOAD="$so"  # faster malloc
    break
  fi
done

export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=${REPRO_DEVICES:-8}}"
if [[ "${REPRO_X64:-0}" == "1" ]]; then
  export JAX_ENABLE_X64=1
fi
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python "$@"
